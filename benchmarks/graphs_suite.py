"""Benchmark graph suite: one laptop-scale member per paper graph class."""
from repro.data import graphs as gen

SUITE = {
    # name -> (factory kwargs, paper class)
    "grid": (lambda: gen.grid2d(96, 96), "artificial mesh (2D)"),
    "cube": (lambda: gen.grid3d(21, 21, 21), "artificial mesh (3D)"),
    "geo": (lambda: gen.random_geometric(8192, seed=1), "finite element"),
    "rmat": (lambda: gen.rmat(scale=13, edge_factor=8, seed=2), "social network"),
    "smallworld": (lambda: gen.small_world(8192, k_ring=6, seed=3),
                   "complex network"),
}


def load(name):
    return SUITE[name][0]()
