"""Table 1/2 + Fig 1-style: end-to-end partitioner quality & time breakdown.

Compares the full Jet partitioner against the same multilevel driver with
size-constrained-LP refinement (our implementable stand-in for the LP-based
competitors), across k and imbalance settings, and reports the paper's
Table 2 phase breakdown (coarsen / initial partition / uncoarsen).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import coarsen as co
from repro.core import initial, metrics
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import PartitionConfig, partition


def _balance_only(g, parts, k, lam):
    """Shared rebalancing (CLP has none; the paper's effectiveness protocol
    likewise hands every refiner a balanced input)."""
    from repro.core import rebalance as rb

    W = g.total_vweight()
    for it in range(k + 4):
        sizes = metrics.part_sizes(g, parts, k)
        if bool(metrics.is_balanced(sizes, W, k, lam)):
            return parts
        fn = rb.jetrw_moves if it < 2 else rb.jetrs_moves
        move, dest = fn(g, parts, k, lam)
        parts = jnp.where(move, dest, parts)
    return parts


def _clp_multilevel(g, k, lam, seed):
    """Same multilevel pipeline, constrained-LP refinement instead of Jet
    (both get balanced inputs at every level; the variable under test is
    the LP-vs-Jetlp cut optimization)."""
    levels = co.multilevel_coarsen(g, coarse_target=max(1024, 8 * k),
                                   seed=seed)
    gc = levels[-1].graph
    parts = initial.initial_partition(gc, k, seed=seed)
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        parts = _balance_only(gi, parts, k, lam)
        parts, _ = constrained_lp_refine(gi, parts, k, lam=lam, iters=24)
        if i > 0:
            parts = co.project_partition(levels[i - 1].cmap, parts)
            parts = jnp.where(levels[i - 1].graph.vertex_mask(), parts, k)
    return _balance_only(g, parts, k, lam)


def quality(ks=(8, 32), lams=(0.03,), seeds=(0,), quick=False):
    names = list(SUITE) if not quick else ["grid", "rmat"]
    if quick:
        ks, seeds = (8,), (0,)
    rows = []
    for k in ks:
        for lam in lams:
            ratios = []
            for name in names:
                g = load(name)
                jax.clear_caches()
                for seed in seeds:
                    cfg = PartitionConfig(k=k, lam=lam, seed=seed,
                                          coarse_target=max(1024, 8 * k))
                    jet = partition(g, cfg)
                    clp_parts = _clp_multilevel(g, k, lam, seed)
                    clp_cut = int(metrics.cutsize(g, clp_parts))
                    ratios.append(clp_cut / max(jet.cut, 1))
            gm = float(np.exp(np.mean(np.log(ratios))))
            rows.append((f"partitioner/clp_over_jet_k{k}_lam{lam}", gm))
    return rows


def time_breakdown(quick=False):
    names = list(SUITE) if not quick else ["grid"]
    rows = []
    for name in names:
        g = load(name)
        cfg = PartitionConfig(k=16, lam=0.03, coarse_target=1024)
        res = partition(g, cfg)
        tot = res.times["total_s"]
        rows.append((f"breakdown/{name}/coarsen_pct",
                     100 * res.times["coarsen_s"] / tot))
        rows.append((f"breakdown/{name}/initpart_pct",
                     100 * res.times["initpart_s"] / tot))
        rows.append((f"breakdown/{name}/uncoarsen_pct",
                     100 * res.times["uncoarsen_s"] / tot))
        rows.append((f"breakdown/{name}/total_s", tot))
    return rows


def main(quick=False):
    rows = quality(quick=quick)
    print("# end-to-end: geomean(CLP-multilevel cut / Jet cut); >1 = Jet wins")
    for name, v in rows:
        print(f"{name},{v:.4f}")
    rows2 = time_breakdown(quick=quick)
    print("# Table 2-style phase breakdown (note: host-loop timings on CPU)")
    for name, v in rows2:
        print(f"{name},{v:.2f}")
    return rows + rows2


if __name__ == "__main__":
    main()
