"""Table 1/2 + Fig 1-style: end-to-end partitioner quality & time breakdown.

Compares the full Jet partitioner against the same multilevel driver with
size-constrained-LP refinement (our implementable stand-in for the LP-based
competitors), across k and imbalance settings, and reports the paper's
Table 2 phase breakdown (coarsen / initial partition / uncoarsen).

Also the device-resident coarsening A/B (DESIGN.md §8): phase timings for
``coarsen_mode="host"`` (legacy numpy repack) vs ``"device"`` (one jitted
kernel per level on the static shape schedule), and the batched-trials A/B
(DESIGN.md §9): a sequential T-loop vs one vmapped best-of-T batch, gated
on per-trial cut equivalence and on the compile count (one
``uncoarsen_level`` executable per capacity-rung signature regardless of
T).  All written to ``BENCH_partitioner.json``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import coarsen as co
from repro.core import initial, metrics
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import PartitionConfig, partition, uncoarsen_level


def _balance_only(g, parts, k, lam):
    """Shared rebalancing (CLP has none; the paper's effectiveness protocol
    likewise hands every refiner a balanced input)."""
    from repro.core import rebalance as rb

    W = g.total_vweight()
    for it in range(k + 4):
        sizes = metrics.part_sizes(g, parts, k)
        if bool(metrics.is_balanced(sizes, W, k, lam)):
            return parts
        fn = rb.jetrw_moves if it < 2 else rb.jetrs_moves
        move, dest = fn(g, parts, k, lam)
        parts = jnp.where(move, dest, parts)
    return parts


def _clp_multilevel(g, k, lam, seed):
    """Same multilevel pipeline, constrained-LP refinement instead of Jet
    (both get balanced inputs at every level; the variable under test is
    the LP-vs-Jetlp cut optimization)."""
    levels = co.multilevel_coarsen(g, coarse_target=max(1024, 8 * k),
                                   seed=seed)
    gc = levels[-1].graph
    parts = initial.initial_partition(gc, k, seed=seed)
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        parts = _balance_only(gi, parts, k, lam)
        parts, _ = constrained_lp_refine(gi, parts, k, lam=lam, iters=24)
        if i > 0:
            parts = co.project_partition(levels[i - 1].cmap, parts)
            parts = jnp.where(levels[i - 1].graph.vertex_mask(), parts, k)
    return _balance_only(g, parts, k, lam)


def quality(ks=(8, 32), lams=(0.03,), seeds=(0,), quick=False):
    names = list(SUITE) if not quick else ["grid", "rmat"]
    if quick:
        ks, seeds = (8,), (0,)
    rows = []
    for k in ks:
        for lam in lams:
            ratios = []
            for name in names:
                g = load(name)
                jax.clear_caches()
                for seed in seeds:
                    cfg = PartitionConfig(k=k, lam=lam, seed=seed,
                                          coarse_target=max(1024, 8 * k))
                    jet = partition(g, cfg)
                    clp_parts = _clp_multilevel(g, k, lam, seed)
                    clp_cut = int(metrics.cutsize(g, clp_parts))
                    ratios.append(clp_cut / max(jet.cut, 1))
            gm = float(np.exp(np.mean(np.log(ratios))))
            rows.append((f"partitioner/clp_over_jet_k{k}_lam{lam}", gm))
    return rows


def time_breakdown(quick=False):
    names = list(SUITE) if not quick else ["grid"]
    rows = []
    for name in names:
        g = load(name)
        cfg = PartitionConfig(k=16, lam=0.03, coarse_target=1024)
        res = partition(g, cfg)
        tot = res.times["total_s"]
        rows.append((f"breakdown/{name}/coarsen_pct",
                     100 * res.times["coarsen_s"] / tot))
        rows.append((f"breakdown/{name}/initpart_pct",
                     100 * res.times["initpart_s"] / tot))
        rows.append((f"breakdown/{name}/uncoarsen_pct",
                     100 * res.times["uncoarsen_s"] / tot))
        rows.append((f"breakdown/{name}/total_s", tot))
    return rows


def coarsen_mode_ab(names=None, k=16, coarse_target=1024, reps=2,
                    cfg_extra=None):
    """Host-repack vs device-resident coarsening: per-phase wall time.

    Each mode runs once cold (compile) then ``reps`` timed repetitions;
    cuts must agree (both paths walk the same hierarchy).
    """
    if names is None:
        names = list(SUITE)
    graphs = {n: load(n) for n in names} if isinstance(names, list) else names
    out = {}
    for name, g in graphs.items():
        rec = {}
        for mode in ("host", "device"):
            jax.clear_caches()
            cfg = PartitionConfig(k=k, coarse_target=coarse_target,
                                  coarsen_mode=mode, **(cfg_extra or {}))
            res = partition(g, cfg)  # cold: includes compilation
            timed = []
            for _ in range(reps):
                timed.append(partition(g, cfg))
            cuts = {res.cut} | {t.cut for t in timed}
            if len(cuts) != 1:
                raise AssertionError(
                    f"{name}/{mode}: nondeterministic cuts across reps {cuts}"
                )
            rec[mode] = {
                "cut": res.cut,
                "levels": res.levels,
                "cold": res.times,
                "warm": {
                    ph: float(np.mean([t.times[ph] for t in timed]))
                    for ph in ("coarsen_s", "initpart_s", "uncoarsen_s",
                               "total_s")
                },
                "level_capacity": [
                    (st["n"], st["m"], st["n_max"], st["m_max"])
                    for st in res.level_stats
                ],
            }
        if rec["host"]["cut"] != rec["device"]["cut"]:
            raise AssertionError(
                f"{name}: host/device coarsening diverged — "
                f"host cut {rec['host']['cut']} vs device "
                f"{rec['device']['cut']}"
            )
        for phase in ("coarsen_s", "total_s"):
            rec[f"speedup_{phase}"] = (
                rec["host"]["warm"][phase]
                / max(rec["device"]["warm"][phase], 1e-9)
            )
        out[name] = rec
    return out


def _rung_signatures(res):
    """Distinct uncoarsen_level compile signatures a run must have hit:
    (fine n_max, fine m_max, coarse n_max, c-ratio) plus, on the ELL
    backend, the per-level static max_degree (it sizes the ELL arrays, so
    it is part of the jit key).  level_stats is ordered coarsest first;
    the coarsest call projects through the identity cmap (its own
    capacity)."""
    cfg = res.config
    sigs = set()
    for j, st in enumerate(res.level_stats):
        nc = st["n_max"] if j == 0 else res.level_stats[j - 1]["n_max"]
        c = cfg.c_finest if st["level"] == 0 else cfg.c_coarse
        md = st.get("max_degree") if cfg.backend == "ell" else None
        sigs.add((st["n_max"], st["m_max"], nc, c, md))
    return sigs


def trials_ab(names=None, k=8, trials=4, coarse_target=512, cfg_extra=None):
    """Sequential T-loop vs one vmapped best-of-T batch (DESIGN.md §9).

    Gates: (1) every vmapped trial's cut is bit-identical to the sequential
    run with that trial's seed; (2) the selected best-of-T cut is <= every
    balanced single-trial cut; (3) the batched run compiles exactly one
    ``uncoarsen_level`` executable per capacity-rung signature — T rides
    the batch axis, it never multiplies executables.
    """
    if names is None:
        names = list(SUITE)
    graphs = {n: load(n) for n in names} if isinstance(names, list) else names
    out = {}
    for name, g in graphs.items():
        base = dict(k=k, coarse_target=coarse_target, **(cfg_extra or {}))
        jax.clear_caches()
        t0 = time.perf_counter()
        seq = [
            partition(g, PartitionConfig(**base, trials=1, trial_seeds=(t,)))
            for t in range(trials)
        ]
        seq_cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for t in range(trials):
            partition(g, PartitionConfig(**base, trials=1, trial_seeds=(t,)))
        seq_warm_s = time.perf_counter() - t0

        jax.clear_caches()
        cfg_b = PartitionConfig(**base, trials=trials,
                                trial_seeds=tuple(range(trials)))
        execs0 = uncoarsen_level._cache_size()
        t0 = time.perf_counter()
        res = partition(g, cfg_b)
        bat_cold_s = time.perf_counter() - t0
        execs = uncoarsen_level._cache_size() - execs0
        t0 = time.perf_counter()
        partition(g, cfg_b)
        bat_warm_s = time.perf_counter() - t0

        # gate 1: per-trial cut equivalence, bit-identical
        for t in range(trials):
            if res.trial_cuts[t] != seq[t].cut:
                raise AssertionError(
                    f"{name}: vmapped trial {t} cut {res.trial_cuts[t]} != "
                    f"sequential cut {seq[t].cut}"
                )
        # gate 2: best-of-T never loses to a balanced single trial
        bal_cuts = [s.cut for s in seq if s.balanced]
        if bal_cuts and res.cut > min(bal_cuts):
            raise AssertionError(
                f"{name}: best-of-{trials} cut {res.cut} > best sequential "
                f"balanced cut {min(bal_cuts)}"
            )
        # gate 3: one executable per rung signature, regardless of T
        expected = len(_rung_signatures(res))
        if execs != expected:
            raise AssertionError(
                f"{name}: {execs} uncoarsen_level executables for "
                f"{expected} rung signatures — trial batching must not "
                f"multiply compiles"
            )
        out[name] = {
            "trials": trials,
            "trial_cuts": res.trial_cuts,
            "best_trial": res.best_trial,
            "best_cut": res.cut,
            "single_trial_cut": seq[0].cut,
            "seq_cold_s": seq_cold_s,
            "seq_warm_s": seq_warm_s,
            "batch_cold_s": bat_cold_s,
            "batch_warm_s": bat_warm_s,
            "warm_speedup": seq_warm_s / max(bat_warm_s, 1e-9),
            "rung_executables": execs,
        }
    return out


def main(quick=False, smoke=False, json_path="BENCH_partitioner.json",
         trials=0):
    trials_full = trials or 4  # full-run default when --trials is omitted
    report = {}
    if smoke:
        # CI guard: tiny graph, one rep — exercises both coarsening modes
        # (and, with --trials N, the batched best-of-N path) end to end so
        # the bench script can't silently rot.  Smoke runs MERGE into an
        # existing report so the coarsen and trials smoke steps compose.
        from repro.data import graphs as gen

        try:
            with open(json_path) as f:
                report = json.load(f)
        except (OSError, ValueError):
            report = {}
        if trials > 1:
            tab = trials_ab(names={"smoke": gen.grid2d(16, 16)}, k=4,
                            trials=trials, coarse_target=32,
                            cfg_extra={"max_iter": 40, "patience": 4})
            report.setdefault("trials_ab", {}).update(tab)
            print(json.dumps(tab["smoke"], indent=1))
        else:
            ab = coarsen_mode_ab(names={"smoke": gen.grid2d(16, 16)}, k=4,
                                 coarse_target=32, reps=1,
                                 cfg_extra={"max_iter": 40, "patience": 4})
            report.setdefault("coarsen_mode_ab", {}).update(ab)
            print(json.dumps(ab["smoke"], indent=1))
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"-> {json_path}")
        return report

    rows = quality(quick=quick)
    print("# end-to-end: geomean(CLP-multilevel cut / Jet cut); >1 = Jet wins")
    for name, v in rows:
        print(f"{name},{v:.4f}")
    rows2 = time_breakdown(quick=quick)
    print("# Table 2-style phase breakdown (note: host-loop timings on CPU)")
    for name, v in rows2:
        print(f"{name},{v:.2f}")
    ab = coarsen_mode_ab(names=["grid", "rmat"] if quick else None,
                         reps=1 if quick else 2)
    print("# coarsen A/B: host repack vs device-resident (warm total)")
    for name, rec in ab.items():
        print(f"coarsen_ab/{name}/coarsen_speedup,"
              f"{rec['speedup_coarsen_s']:.3f}")
    tab = trials_ab(names=["grid", "rmat"] if quick else None,
                    trials=trials_full)
    print(f"# trials A/B: sequential {trials_full}-loop vs vmapped batch "
          "(warm)")
    for name, rec in tab.items():
        print(f"trials_ab/{name}/warm_speedup,{rec['warm_speedup']:.3f}")
        print(f"trials_ab/{name}/best_of_{trials_full}_cut,{rec['best_cut']}")
        print(f"trials_ab/{name}/single_trial_cut,{rec['single_trial_cut']}")
    report["quality"] = dict(rows)
    report["breakdown"] = dict(rows2)
    report["coarsen_mode_ab"] = ab
    report["trials_ab"] = tab
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, 1 rep — CI guard for the bench script")
    ap.add_argument("--trials", type=int, default=0,
                    help="trial count for the batched best-of-N A/B "
                         "(default 4 for full runs); with --smoke, >1 runs "
                         "the trials smoke instead of the coarsen-mode one")
    ap.add_argument("--json", default="BENCH_partitioner.json")
    a = ap.parse_args()
    main(quick=a.quick, smoke=a.smoke, json_path=a.json, trials=a.trials)
