"""Table 1/2 + Fig 1-style: end-to-end partitioner quality & time breakdown.

Compares the full Jet partitioner against the same multilevel driver with
size-constrained-LP refinement (our implementable stand-in for the LP-based
competitors), across k and imbalance settings, and reports the paper's
Table 2 phase breakdown (coarsen / initial partition / uncoarsen).

Also the device-resident coarsening A/B (DESIGN.md §8): phase timings for
``coarsen_mode="host"`` (legacy numpy repack) vs ``"device"`` (one jitted
kernel per level on the static shape schedule), and the batched-trials A/B
(DESIGN.md §9): a sequential T-loop vs one vmapped best-of-T batch, gated
on per-trial cut equivalence and on the compile count (one
``uncoarsen_level`` executable per capacity-rung signature regardless of
T), and the fleet A/B (DESIGN.md §10): a sequential per-graph loop vs one
shape-bucketed batched fleet, gated on per-graph bit-equivalence and the
per-(rung, batch)-signature executable count.  All written to
``BENCH_partitioner.json``.

``--check-baseline`` is the CI quality-regression gate: it re-runs the
smoke suite into a fresh JSON and exits nonzero when any smoke cut grows
past the baseline's tolerance tag (or a baseline-balanced member goes
unbalanced).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import coarsen as co
from repro.core import initial, metrics
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import (
    PartitionConfig, partition, partition_fleet, uncoarsen_level,
    uncoarsen_level_fleet,
)


def _balance_only(g, parts, k, lam):
    """Shared rebalancing (CLP has none; the paper's effectiveness protocol
    likewise hands every refiner a balanced input)."""
    from repro.core import rebalance as rb

    W = g.total_vweight()
    for it in range(k + 4):
        sizes = metrics.part_sizes(g, parts, k)
        if bool(metrics.is_balanced(sizes, W, k, lam)):
            return parts
        fn = rb.jetrw_moves if it < 2 else rb.jetrs_moves
        move, dest = fn(g, parts, k, lam)
        parts = jnp.where(move, dest, parts)
    return parts


def _clp_multilevel(g, k, lam, seed):
    """Same multilevel pipeline, constrained-LP refinement instead of Jet
    (both get balanced inputs at every level; the variable under test is
    the LP-vs-Jetlp cut optimization)."""
    levels = co.multilevel_coarsen(g, coarse_target=max(1024, 8 * k),
                                   seed=seed)
    gc = levels[-1].graph
    parts = initial.initial_partition(gc, k, seed=seed)
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        parts = _balance_only(gi, parts, k, lam)
        parts, _ = constrained_lp_refine(gi, parts, k, lam=lam, iters=24)
        if i > 0:
            parts = co.project_partition(levels[i - 1].cmap, parts)
            parts = jnp.where(levels[i - 1].graph.vertex_mask(), parts, k)
    return _balance_only(g, parts, k, lam)


def quality(ks=(8, 32), lams=(0.03,), seeds=(0,), quick=False):
    names = list(SUITE) if not quick else ["grid", "rmat"]
    if quick:
        ks, seeds = (8,), (0,)
    rows = []
    for k in ks:
        for lam in lams:
            ratios = []
            for name in names:
                g = load(name)
                jax.clear_caches()
                for seed in seeds:
                    cfg = PartitionConfig(k=k, lam=lam, seed=seed,
                                          coarse_target=max(1024, 8 * k))
                    jet = partition(g, cfg)
                    clp_parts = _clp_multilevel(g, k, lam, seed)
                    clp_cut = int(metrics.cutsize(g, clp_parts))
                    ratios.append(clp_cut / max(jet.cut, 1))
            gm = float(np.exp(np.mean(np.log(ratios))))
            rows.append((f"partitioner/clp_over_jet_k{k}_lam{lam}", gm))
    return rows


def time_breakdown(quick=False):
    names = list(SUITE) if not quick else ["grid"]
    rows = []
    for name in names:
        g = load(name)
        cfg = PartitionConfig(k=16, lam=0.03, coarse_target=1024)
        res = partition(g, cfg)
        tot = res.times["total_s"]
        rows.append((f"breakdown/{name}/coarsen_pct",
                     100 * res.times["coarsen_s"] / tot))
        rows.append((f"breakdown/{name}/initpart_pct",
                     100 * res.times["initpart_s"] / tot))
        rows.append((f"breakdown/{name}/uncoarsen_pct",
                     100 * res.times["uncoarsen_s"] / tot))
        rows.append((f"breakdown/{name}/total_s", tot))
    return rows


def coarsen_mode_ab(names=None, k=16, coarse_target=1024, reps=2,
                    cfg_extra=None):
    """Host-repack vs device-resident coarsening: per-phase wall time.

    Each mode runs once cold (compile) then ``reps`` timed repetitions;
    cuts must agree (both paths walk the same hierarchy).
    """
    if names is None:
        names = list(SUITE)
    graphs = {n: load(n) for n in names} if isinstance(names, list) else names
    out = {}
    for name, g in graphs.items():
        rec = {}
        for mode in ("host", "device"):
            jax.clear_caches()
            cfg = PartitionConfig(k=k, coarse_target=coarse_target,
                                  coarsen_mode=mode, **(cfg_extra or {}))
            res = partition(g, cfg)  # cold: includes compilation
            timed = []
            for _ in range(reps):
                timed.append(partition(g, cfg))
            cuts = {res.cut} | {t.cut for t in timed}
            if len(cuts) != 1:
                raise AssertionError(
                    f"{name}/{mode}: nondeterministic cuts across reps {cuts}"
                )
            rec[mode] = {
                "cut": res.cut,
                "levels": res.levels,
                "cold": res.times,
                "warm": {
                    ph: float(np.mean([t.times[ph] for t in timed]))
                    for ph in ("coarsen_s", "initpart_s", "uncoarsen_s",
                               "total_s")
                },
                "level_capacity": [
                    (st["n"], st["m"], st["n_max"], st["m_max"])
                    for st in res.level_stats
                ],
            }
        if rec["host"]["cut"] != rec["device"]["cut"]:
            raise AssertionError(
                f"{name}: host/device coarsening diverged — "
                f"host cut {rec['host']['cut']} vs device "
                f"{rec['device']['cut']}"
            )
        for phase in ("coarsen_s", "total_s"):
            rec[f"speedup_{phase}"] = (
                rec["host"]["warm"][phase]
                / max(rec["device"]["warm"][phase], 1e-9)
            )
        out[name] = rec
    return out


def _rung_signatures(res):
    """Distinct uncoarsen_level compile signatures a run must have hit:
    (fine n_max, fine m_max, coarse n_max, c-ratio) plus, on the ELL
    backend, the per-level static max_degree (it sizes the ELL arrays, so
    it is part of the jit key).  level_stats is ordered coarsest first;
    the coarsest call projects through the identity cmap (its own
    capacity)."""
    cfg = res.config
    sigs = set()
    for j, st in enumerate(res.level_stats):
        nc = st["n_max"] if j == 0 else res.level_stats[j - 1]["n_max"]
        c = cfg.c_finest if st["level"] == 0 else cfg.c_coarse
        md = st.get("max_degree") if cfg.backend == "ell" else None
        sigs.add((st["n_max"], st["m_max"], nc, c, md))
    return sigs


def trials_ab(names=None, k=8, trials=4, coarse_target=512, cfg_extra=None):
    """Sequential T-loop vs one vmapped best-of-T batch (DESIGN.md §9).

    Gates: (1) every vmapped trial's cut is bit-identical to the sequential
    run with that trial's seed; (2) the selected best-of-T cut is <= every
    balanced single-trial cut; (3) the batched run compiles exactly one
    ``uncoarsen_level`` executable per capacity-rung signature — T rides
    the batch axis, it never multiplies executables.
    """
    if names is None:
        names = list(SUITE)
    graphs = {n: load(n) for n in names} if isinstance(names, list) else names
    out = {}
    for name, g in graphs.items():
        base = dict(k=k, coarse_target=coarse_target, **(cfg_extra or {}))
        jax.clear_caches()
        t0 = time.perf_counter()
        seq = [
            partition(g, PartitionConfig(**base, trials=1, trial_seeds=(t,)))
            for t in range(trials)
        ]
        seq_cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for t in range(trials):
            partition(g, PartitionConfig(**base, trials=1, trial_seeds=(t,)))
        seq_warm_s = time.perf_counter() - t0

        jax.clear_caches()
        cfg_b = PartitionConfig(**base, trials=trials,
                                trial_seeds=tuple(range(trials)))
        execs0 = uncoarsen_level._cache_size()
        t0 = time.perf_counter()
        res = partition(g, cfg_b)
        bat_cold_s = time.perf_counter() - t0
        execs = uncoarsen_level._cache_size() - execs0
        t0 = time.perf_counter()
        partition(g, cfg_b)
        bat_warm_s = time.perf_counter() - t0

        # gate 1: per-trial cut equivalence, bit-identical
        for t in range(trials):
            if res.trial_cuts[t] != seq[t].cut:
                raise AssertionError(
                    f"{name}: vmapped trial {t} cut {res.trial_cuts[t]} != "
                    f"sequential cut {seq[t].cut}"
                )
        # gate 2: best-of-T never loses to a balanced single trial
        bal_cuts = [s.cut for s in seq if s.balanced]
        if bal_cuts and res.cut > min(bal_cuts):
            raise AssertionError(
                f"{name}: best-of-{trials} cut {res.cut} > best sequential "
                f"balanced cut {min(bal_cuts)}"
            )
        # gate 3: one executable per rung signature, regardless of T
        expected = len(_rung_signatures(res))
        if execs != expected:
            raise AssertionError(
                f"{name}: {execs} uncoarsen_level executables for "
                f"{expected} rung signatures — trial batching must not "
                f"multiply compiles"
            )
        out[name] = {
            "trials": trials,
            "trial_cuts": res.trial_cuts,
            "best_trial": res.best_trial,
            "best_cut": res.cut,
            "single_trial_cut": seq[0].cut,
            "seq_cold_s": seq_cold_s,
            "seq_warm_s": seq_warm_s,
            "batch_cold_s": bat_cold_s,
            "batch_warm_s": bat_warm_s,
            "warm_speedup": seq_warm_s / max(bat_warm_s, 1e-9),
            "rung_executables": execs,
        }
    return out


def _fleet_signatures(fres):
    """Distinct ``uncoarsen_level_fleet`` compile signatures a fleet run
    must have hit: (B, T, fine n_max, fine m_max, nc_max, c-ratio, ell
    width).  The same counting rule as :func:`_rung_signatures`, extended
    by the batch shape — two buckets with equal B and equal rungs SHARE
    executables, which is the point of the shape-bucketed fleet."""
    cfg = fres.config
    sigs = set()
    for b in fres.buckets:
        B = len(b.indices)
        for j, st in enumerate(b.level_stats):
            nc = st["n_max"] if j == 0 else b.level_stats[j - 1]["n_max"]
            c = cfg.c_finest if st["level"] == 0 else cfg.c_coarse
            md = st.get("ell_width") if cfg.backend == "ell" else None
            sigs.add((B, fres.trials, st["n_max"], st["m_max"], nc, c, md))
    return sigs


def fleet_ab(graphs=None, k=8, trials=1, coarse_target=512, cfg_extra=None):
    """Sequential per-graph loop vs one shape-bucketed batched fleet
    (DESIGN.md §10).

    Gates: (1) every fleet member's cut, balance flag, and per-trial cuts
    are bit-identical to its standalone ``partition()`` run; (2) the fleet
    compiles exactly one ``uncoarsen_level_fleet`` executable per (rung,
    batch) signature — B and T ride batch axes, they never multiply
    executables; (3) the fleet exercises mixed bucket occupancy (some
    bucket holds graphs of different true sizes).
    """
    if graphs is None:
        from repro.data import graphs as gen

        # mixed sizes on purpose: grid96/grid90 round to a shared capacity
        # rung (mixed bucket occupancy), grid48 lands in its own bucket
        graphs = {
            "grid96": gen.grid2d(96, 96),
            "grid90": gen.grid2d(90, 90),
            "grid48": gen.grid2d(48, 48),
        }
    names = list(graphs)
    glist = [graphs[n] for n in names]
    base = dict(k=k, coarse_target=coarse_target, trials=trials,
                **(cfg_extra or {}))

    jax.clear_caches()
    t0 = time.perf_counter()
    seq = [partition(g, PartitionConfig(**base)) for g in glist]
    seq_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for g in glist:
        partition(g, PartitionConfig(**base))
    seq_warm_s = time.perf_counter() - t0

    jax.clear_caches()
    execs0 = uncoarsen_level_fleet._cache_size()
    t0 = time.perf_counter()
    fres = partition_fleet(glist, PartitionConfig(**base))
    fleet_cold_s = time.perf_counter() - t0
    execs = uncoarsen_level_fleet._cache_size() - execs0
    t0 = time.perf_counter()
    partition_fleet(glist, PartitionConfig(**base))
    fleet_warm_s = time.perf_counter() - t0

    # gate 1: per-graph bit-equivalence with the standalone runs
    for i, name in enumerate(names):
        fr, sr = fres.results[i], seq[i]
        if (fr.cut, fr.balanced, fr.trial_cuts) != \
                (sr.cut, sr.balanced, sr.trial_cuts):
            raise AssertionError(
                f"fleet/{name}: batched run diverged — fleet "
                f"(cut={fr.cut}, balanced={fr.balanced}, "
                f"trial_cuts={fr.trial_cuts}) vs standalone "
                f"(cut={sr.cut}, balanced={sr.balanced}, "
                f"trial_cuts={sr.trial_cuts})"
            )
    # gate 2: one executable per (rung, batch) signature
    expected = len(_fleet_signatures(fres))
    if execs != expected:
        raise AssertionError(
            f"{execs} uncoarsen_level_fleet executables for {expected} "
            "bucket-rung signatures — fleet batching must not multiply "
            "compiles"
        )
    # gate 3: the fleet must actually exercise mixed bucket occupancy
    mixed = any(len(b.indices) >= 2 for b in fres.buckets)
    if len(glist) >= 3 and not mixed:
        raise AssertionError(
            "no bucket holds >= 2 graphs — pick fleet members whose sizes "
            "round to a shared capacity rung"
        )
    return {
        "members": names,
        "cuts": {n: fres.results[i].cut for i, n in enumerate(names)},
        "balanced": {n: fres.results[i].balanced
                     for i, n in enumerate(names)},
        "buckets": [
            {"capacity": list(b.capacity),
             "members": [names[i] for i in b.indices],
             "levels": b.levels}
            for b in fres.buckets
        ],
        "trials": trials,
        "seq_cold_s": seq_cold_s,
        "seq_warm_s": seq_warm_s,
        "fleet_cold_s": fleet_cold_s,
        "fleet_warm_s": fleet_warm_s,
        "warm_speedup": seq_warm_s / max(fleet_warm_s, 1e-9),
        "bucket_executables": execs,
    }


# ---------------------------------------------------------------------------
# CI quality-regression gate (--check-baseline)
# ---------------------------------------------------------------------------

BASELINE_TOLERANCE = 0.05  # default: a cut may grow by at most 5%


def _cut_metrics(report):
    """Flatten the quality-critical numbers of a bench report:
    ``{metric_path: (cut value | balanced flag)}``."""
    cuts, balanced = {}, {}
    for name, rec in report.get("coarsen_mode_ab", {}).items():
        for mode in ("host", "device"):
            if mode in rec:
                cuts[f"coarsen_mode_ab/{name}/{mode}/cut"] = rec[mode]["cut"]
    for name, rec in report.get("trials_ab", {}).items():
        cuts[f"trials_ab/{name}/best_cut"] = rec["best_cut"]
        for t, c in enumerate(rec.get("trial_cuts", [])):
            cuts[f"trials_ab/{name}/trial{t}/cut"] = c
    for name, rec in report.get("fleet_ab", {}).items():
        for gname, c in rec.get("cuts", {}).items():
            cuts[f"fleet_ab/{name}/{gname}/cut"] = c
        for gname, b in rec.get("balanced", {}).items():
            balanced[f"fleet_ab/{name}/{gname}/balanced"] = b
    return cuts, balanced


def compare_baseline(fresh, baseline, tolerance=None):
    """Quality-regression check: fresh smoke numbers vs the committed
    baseline.  Returns a list of human-readable regression strings (empty
    == gate passes).  Only metrics present in BOTH reports are compared;
    the baseline may carry its own tolerance tag (``baseline_tolerance``),
    which ``tolerance`` overrides when given."""
    tol = tolerance if tolerance is not None else \
        baseline.get("baseline_tolerance", BASELINE_TOLERANCE)
    fresh_cuts, fresh_bal = _cut_metrics(fresh)
    base_cuts, base_bal = _cut_metrics(baseline)
    bad = []
    # every baseline SMOKE metric must still exist in the fresh run — a
    # renamed/dropped smoke entry would otherwise silently leave the gate
    # (full-run entries in the baseline are legitimately absent from a
    # smoke-only fresh report, so only /smoke keys are required)
    for key in sorted(k for k in set(base_cuts) | set(base_bal)
                      if "/smoke" in k):
        if key not in fresh_cuts and key not in fresh_bal:
            bad.append(
                f"{key}: present in baseline but missing from the fresh "
                "run — smoke metrics may not be dropped or renamed without "
                "regenerating the baseline"
            )
    for key in sorted(set(fresh_cuts) & set(base_cuts)):
        allowed = base_cuts[key] * (1.0 + tol)
        if fresh_cuts[key] > allowed:
            bad.append(
                f"{key}: cut {fresh_cuts[key]} exceeds baseline "
                f"{base_cuts[key]} by more than {100 * tol:.1f}%"
            )
    for key in sorted(set(fresh_bal) & set(base_bal)):
        if base_bal[key] and not fresh_bal[key]:
            bad.append(f"{key}: baseline was balanced, fresh run is not")
    common = (set(fresh_cuts) & set(base_cuts)) | \
        (set(fresh_bal) & set(base_bal))
    if not common:
        bad.append(
            "no comparable metrics between fresh report and baseline — "
            "the gate would pass vacuously; regenerate the baseline"
        )
    return bad


def check_baseline(baseline_path="BENCH_partitioner.json",
                   json_path="BENCH_partitioner.fresh.json",
                   tolerance=None,
                   serve_baseline_path="BENCH_serve.json",
                   serve_fresh_path=None):
    """Run the smoke suite fresh, then gate cut/balance against the
    committed baseline.  Returns a process exit code.

    When a serving baseline (``BENCH_serve.json``) is committed, the gate
    also covers the §11 serving path: throughput and batch occupancy from
    a fresh serve smoke are compared under the baseline's tolerance tags.
    ``serve_fresh_path`` reuses an existing fresh serve report (the CI
    serve-smoke job's artifact) instead of replaying the burst again; by
    default the dense-backend smoke is re-run here.
    """
    import os

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read baseline {baseline_path}: {e}")
        return 2
    # start from an EMPTY fresh report: a stale json at json_path would
    # merge never-re-run numbers into the comparison and mask regressions
    try:
        os.remove(json_path)
    except OSError:
        pass
    # a fresh smoke pass across all three A/Bs, merged into json_path
    main(smoke=True, json_path=json_path)
    main(smoke=True, json_path=json_path, trials=2)
    fresh = main(smoke=True, json_path=json_path, fleet=True)
    regressions = compare_baseline(fresh, baseline, tolerance=tolerance)

    # serving-path gate (bench_serve): same pattern — committed baseline,
    # fresh numbers, tolerance tags from the baseline JSON
    serve_baseline = None
    try:
        with open(serve_baseline_path) as f:
            serve_baseline = json.load(f)
    except (OSError, ValueError):
        print(f"no serving baseline at {serve_baseline_path} — "
              "serve gate skipped")
    if serve_baseline is not None:
        from benchmarks.bench_serve import compare_serve_baseline, serve_smoke

        if serve_fresh_path and os.path.exists(serve_fresh_path):
            with open(serve_fresh_path) as f:
                serve_fresh = json.load(f)
        else:
            # serve_smoke MERGES into its json — start empty so stale
            # backend sections can't mask a serving regression
            try:
                os.remove("BENCH_serve.fresh.json")
            except OSError:
                pass
            serve_fresh = serve_smoke(
                backends=("dense",), json_path="BENCH_serve.fresh.json")
        # NOT forwarding `tolerance`: it is the cut-growth override, and
        # loosening cuts must not loosen the structural occupancy gate —
        # the serve gate reads its own tags from the serving baseline
        regressions += compare_serve_baseline(serve_fresh, serve_baseline)

    if regressions:
        print(f"QUALITY GATE FAILED vs {baseline_path}:")
        for r in regressions:
            print(f"  {r}")
        return 1
    print(f"quality gate OK vs {baseline_path} "
          f"({json_path} holds the fresh numbers)")
    return 0


def main(quick=False, smoke=False, json_path="BENCH_partitioner.json",
         trials=0, fleet=False):
    trials_full = trials or 4  # full-run default when --trials is omitted
    report = {}
    if smoke:
        # CI guard: tiny graphs, one rep — exercises both coarsening modes
        # (with --trials N, the batched best-of-N path; with --fleet, the
        # shape-bucketed fleet path) end to end so the bench script can't
        # silently rot.  Smoke runs MERGE into an existing report so the
        # smoke steps compose into one gate-able JSON.
        from repro.data import graphs as gen

        try:
            with open(json_path) as f:
                report = json.load(f)
        except (OSError, ValueError):
            report = {}
        if fleet:
            fab = fleet_ab(
                graphs={"g16": gen.grid2d(16, 16), "g15": gen.grid2d(15, 15),
                        "g8": gen.grid2d(8, 8)},
                k=4, trials=max(trials, 1), coarse_target=32,
                cfg_extra={"max_iter": 40, "patience": 4},
            )
            report.setdefault("fleet_ab", {})["smoke"] = fab
            print(json.dumps(fab, indent=1))
        elif trials > 1:
            tab = trials_ab(names={"smoke": gen.grid2d(16, 16)}, k=4,
                            trials=trials, coarse_target=32,
                            cfg_extra={"max_iter": 40, "patience": 4})
            report.setdefault("trials_ab", {}).update(tab)
            print(json.dumps(tab["smoke"], indent=1))
        else:
            ab = coarsen_mode_ab(names={"smoke": gen.grid2d(16, 16)}, k=4,
                                 coarse_target=32, reps=1,
                                 cfg_extra={"max_iter": 40, "patience": 4})
            report.setdefault("coarsen_mode_ab", {}).update(ab)
            print(json.dumps(ab["smoke"], indent=1))
        report.setdefault("baseline_tolerance", BASELINE_TOLERANCE)
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"-> {json_path}")
        return report

    # full runs also MERGE: the committed JSON doubles as the CI quality
    # baseline, whose smoke entries a from-scratch rewrite would destroy
    try:
        with open(json_path) as f:
            report = json.load(f)
    except (OSError, ValueError):
        report = {}

    rows = quality(quick=quick)
    print("# end-to-end: geomean(CLP-multilevel cut / Jet cut); >1 = Jet wins")
    for name, v in rows:
        print(f"{name},{v:.4f}")
    rows2 = time_breakdown(quick=quick)
    print("# Table 2-style phase breakdown (note: host-loop timings on CPU)")
    for name, v in rows2:
        print(f"{name},{v:.2f}")
    ab = coarsen_mode_ab(names=["grid", "rmat"] if quick else None,
                         reps=1 if quick else 2)
    print("# coarsen A/B: host repack vs device-resident (warm total)")
    for name, rec in ab.items():
        print(f"coarsen_ab/{name}/coarsen_speedup,"
              f"{rec['speedup_coarsen_s']:.3f}")
    tab = trials_ab(names=["grid", "rmat"] if quick else None,
                    trials=trials_full)
    print(f"# trials A/B: sequential {trials_full}-loop vs vmapped batch "
          "(warm)")
    for name, rec in tab.items():
        print(f"trials_ab/{name}/warm_speedup,{rec['warm_speedup']:.3f}")
        print(f"trials_ab/{name}/best_of_{trials_full}_cut,{rec['best_cut']}")
        print(f"trials_ab/{name}/single_trial_cut,{rec['single_trial_cut']}")
    fab = fleet_ab(coarse_target=1024, trials=trials_full)
    print("# fleet A/B: sequential per-graph loop vs shape-bucketed batch")
    print(f"fleet_ab/mixed/warm_speedup,{fab['warm_speedup']:.3f}")
    print(f"fleet_ab/mixed/bucket_executables,{fab['bucket_executables']}")
    report["quality"] = dict(rows)
    report["breakdown"] = dict(rows2)
    report.setdefault("coarsen_mode_ab", {}).update(ab)
    report.setdefault("trials_ab", {}).update(tab)
    report.setdefault("fleet_ab", {})["mixed"] = fab
    report.setdefault("baseline_tolerance", BASELINE_TOLERANCE)
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, 1 rep — CI guard for the bench script")
    ap.add_argument("--trials", type=int, default=0,
                    help="trial count for the batched best-of-N A/B "
                         "(default 4 for full runs); with --smoke, >1 runs "
                         "the trials smoke instead of the coarsen-mode one")
    ap.add_argument("--fleet", action="store_true",
                    help="with --smoke: run the shape-bucketed fleet A/B "
                         "smoke instead of the coarsen-mode one")
    ap.add_argument("--check-baseline", action="store_true",
                    help="CI quality gate: run the smoke suite fresh and "
                         "exit nonzero if cut/balance regress against the "
                         "committed baseline JSON")
    ap.add_argument("--baseline", default="BENCH_partitioner.json",
                    help="baseline JSON for --check-baseline")
    ap.add_argument("--serve-baseline", default="BENCH_serve.json",
                    help="serving baseline JSON for --check-baseline "
                         "(skipped when absent)")
    ap.add_argument("--serve-fresh", default=None,
                    help="reuse this fresh serve report for the serving "
                         "gate instead of re-running the serve smoke")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override the baseline's cut-growth tolerance")
    ap.add_argument("--json", default=None,
                    help="report JSON path (default: the committed "
                         "BENCH_partitioner.json; with --check-baseline, a "
                         "separate BENCH_partitioner.fresh.json so the "
                         "baseline is never clobbered)")
    a = ap.parse_args()
    if a.check_baseline:
        raise SystemExit(check_baseline(
            baseline_path=a.baseline,
            json_path=a.json or "BENCH_partitioner.fresh.json",
            tolerance=a.tolerance,
            serve_baseline_path=a.serve_baseline,
            serve_fresh_path=a.serve_fresh,
        ))
    main(quick=a.quick, smoke=a.smoke,
         json_path=a.json or "BENCH_partitioner.json", trials=a.trials,
         fleet=a.fleet)
