"""Table 1/2 + Fig 1-style: end-to-end partitioner quality & time breakdown.

Compares the full Jet partitioner against the same multilevel driver with
size-constrained-LP refinement (our implementable stand-in for the LP-based
competitors), across k and imbalance settings, and reports the paper's
Table 2 phase breakdown (coarsen / initial partition / uncoarsen).

Also the device-resident coarsening A/B (DESIGN.md §8): phase timings for
``coarsen_mode="host"`` (legacy numpy repack) vs ``"device"`` (one jitted
kernel per level on the static shape schedule), written to
``BENCH_partitioner.json``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import coarsen as co
from repro.core import initial, metrics
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import PartitionConfig, partition


def _balance_only(g, parts, k, lam):
    """Shared rebalancing (CLP has none; the paper's effectiveness protocol
    likewise hands every refiner a balanced input)."""
    from repro.core import rebalance as rb

    W = g.total_vweight()
    for it in range(k + 4):
        sizes = metrics.part_sizes(g, parts, k)
        if bool(metrics.is_balanced(sizes, W, k, lam)):
            return parts
        fn = rb.jetrw_moves if it < 2 else rb.jetrs_moves
        move, dest = fn(g, parts, k, lam)
        parts = jnp.where(move, dest, parts)
    return parts


def _clp_multilevel(g, k, lam, seed):
    """Same multilevel pipeline, constrained-LP refinement instead of Jet
    (both get balanced inputs at every level; the variable under test is
    the LP-vs-Jetlp cut optimization)."""
    levels = co.multilevel_coarsen(g, coarse_target=max(1024, 8 * k),
                                   seed=seed)
    gc = levels[-1].graph
    parts = initial.initial_partition(gc, k, seed=seed)
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        parts = _balance_only(gi, parts, k, lam)
        parts, _ = constrained_lp_refine(gi, parts, k, lam=lam, iters=24)
        if i > 0:
            parts = co.project_partition(levels[i - 1].cmap, parts)
            parts = jnp.where(levels[i - 1].graph.vertex_mask(), parts, k)
    return _balance_only(g, parts, k, lam)


def quality(ks=(8, 32), lams=(0.03,), seeds=(0,), quick=False):
    names = list(SUITE) if not quick else ["grid", "rmat"]
    if quick:
        ks, seeds = (8,), (0,)
    rows = []
    for k in ks:
        for lam in lams:
            ratios = []
            for name in names:
                g = load(name)
                jax.clear_caches()
                for seed in seeds:
                    cfg = PartitionConfig(k=k, lam=lam, seed=seed,
                                          coarse_target=max(1024, 8 * k))
                    jet = partition(g, cfg)
                    clp_parts = _clp_multilevel(g, k, lam, seed)
                    clp_cut = int(metrics.cutsize(g, clp_parts))
                    ratios.append(clp_cut / max(jet.cut, 1))
            gm = float(np.exp(np.mean(np.log(ratios))))
            rows.append((f"partitioner/clp_over_jet_k{k}_lam{lam}", gm))
    return rows


def time_breakdown(quick=False):
    names = list(SUITE) if not quick else ["grid"]
    rows = []
    for name in names:
        g = load(name)
        cfg = PartitionConfig(k=16, lam=0.03, coarse_target=1024)
        res = partition(g, cfg)
        tot = res.times["total_s"]
        rows.append((f"breakdown/{name}/coarsen_pct",
                     100 * res.times["coarsen_s"] / tot))
        rows.append((f"breakdown/{name}/initpart_pct",
                     100 * res.times["initpart_s"] / tot))
        rows.append((f"breakdown/{name}/uncoarsen_pct",
                     100 * res.times["uncoarsen_s"] / tot))
        rows.append((f"breakdown/{name}/total_s", tot))
    return rows


def coarsen_mode_ab(names=None, k=16, coarse_target=1024, reps=2,
                    cfg_extra=None):
    """Host-repack vs device-resident coarsening: per-phase wall time.

    Each mode runs once cold (compile) then ``reps`` timed repetitions;
    cuts must agree (both paths walk the same hierarchy).
    """
    if names is None:
        names = list(SUITE)
    graphs = {n: load(n) for n in names} if isinstance(names, list) else names
    out = {}
    for name, g in graphs.items():
        rec = {}
        for mode in ("host", "device"):
            jax.clear_caches()
            cfg = PartitionConfig(k=k, coarse_target=coarse_target,
                                  coarsen_mode=mode, **(cfg_extra or {}))
            res = partition(g, cfg)  # cold: includes compilation
            timed = []
            for _ in range(reps):
                timed.append(partition(g, cfg))
            cuts = {res.cut} | {t.cut for t in timed}
            if len(cuts) != 1:
                raise AssertionError(
                    f"{name}/{mode}: nondeterministic cuts across reps {cuts}"
                )
            rec[mode] = {
                "cut": res.cut,
                "levels": res.levels,
                "cold": res.times,
                "warm": {
                    ph: float(np.mean([t.times[ph] for t in timed]))
                    for ph in ("coarsen_s", "initpart_s", "uncoarsen_s",
                               "total_s")
                },
                "level_capacity": [
                    (st["n"], st["m"], st["n_max"], st["m_max"])
                    for st in res.level_stats
                ],
            }
        if rec["host"]["cut"] != rec["device"]["cut"]:
            raise AssertionError(
                f"{name}: host/device coarsening diverged — "
                f"host cut {rec['host']['cut']} vs device "
                f"{rec['device']['cut']}"
            )
        for phase in ("coarsen_s", "total_s"):
            rec[f"speedup_{phase}"] = (
                rec["host"]["warm"][phase]
                / max(rec["device"]["warm"][phase], 1e-9)
            )
        out[name] = rec
    return out


def main(quick=False, smoke=False, json_path="BENCH_partitioner.json"):
    report = {}
    if smoke:
        # CI guard: tiny graph, one rep — exercises both coarsening modes
        # end to end so the bench script can't silently rot.
        from repro.data import graphs as gen

        ab = coarsen_mode_ab(names={"smoke": gen.grid2d(16, 16)}, k=4,
                             coarse_target=32, reps=1,
                             cfg_extra={"max_iter": 40, "patience": 4})
        report["coarsen_mode_ab"] = ab
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(json.dumps(report["coarsen_mode_ab"]["smoke"], indent=1))
        print(f"-> {json_path}")
        return report

    rows = quality(quick=quick)
    print("# end-to-end: geomean(CLP-multilevel cut / Jet cut); >1 = Jet wins")
    for name, v in rows:
        print(f"{name},{v:.4f}")
    rows2 = time_breakdown(quick=quick)
    print("# Table 2-style phase breakdown (note: host-loop timings on CPU)")
    for name, v in rows2:
        print(f"{name},{v:.2f}")
    ab = coarsen_mode_ab(names=["grid", "rmat"] if quick else None,
                         reps=1 if quick else 2)
    print("# coarsen A/B: host repack vs device-resident (warm total)")
    for name, rec in ab.items():
        print(f"coarsen_ab/{name}/coarsen_speedup,"
              f"{rec['speedup_coarsen_s']:.3f}")
    report["quality"] = dict(rows)
    report["breakdown"] = dict(rows2)
    report["coarsen_mode_ab"] = ab
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, 1 rep — CI guard for the bench script")
    ap.add_argument("--json", default="BENCH_partitioner.json")
    a = ap.parse_args()
    main(quick=a.quick, smoke=a.smoke, json_path=a.json)
