"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels themselves are TPU-target; interpret mode timings are
not meaningful), plus ref-vs-kernel parity checks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main(quick=False):
    rows = []
    rng = np.random.default_rng(0)

    from repro.kernels.flash_attention.ref import mha_ref
    from repro.models.attention import chunked_attention

    q = jnp.asarray(rng.standard_normal((1, 4, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    f_ref = jax.jit(lambda q, k, v: mha_ref(q, k, v))
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=128))
    rows.append(("kernel/attn_ref_512", _time(f_ref, q, k, v), ""))
    rows.append(("kernel/attn_chunked_512", _time(f_chunk, q, k, v), ""))

    from repro.kernels.fm_interaction.ref import fm_interaction_ref

    emb = jnp.asarray(rng.standard_normal((4096, 39, 10)).astype(np.float32))
    rows.append(("kernel/fm_ref_4096x39x10",
                 _time(jax.jit(fm_interaction_ref), emb), ""))

    from repro.kernels.segment_reduce.ref import segment_sum_sorted_ref

    seg = jnp.asarray(np.sort(rng.integers(0, 1024, 65536)).astype(np.int32))
    dat = jnp.asarray(rng.standard_normal((65536, 64)).astype(np.float32))
    rows.append(("kernel/segsum_ref_64k",
                 _time(jax.jit(lambda d, s: segment_sum_sorted_ref(d, s, 1024)),
                       dat, seg), ""))

    from repro.core import connectivity as cn
    from repro.data import graphs as gen

    g = gen.rmat(scale=12)
    parts = jnp.asarray(rng.integers(0, 16, g.n_max).astype(np.int32))
    rows.append(("kernel/conn_dense_rmat12",
                 _time(lambda: cn.dense_queries(g, parts, 16)), ""))
    rows.append(("kernel/conn_sorted_rmat12",
                 _time(lambda: cn.sorted_queries(g, parts, 16)), ""))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
