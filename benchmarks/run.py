"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call_or_ratio,derived`` CSV lines.

  bench_component    -> Table 3 (Jetlp ablations)
  bench_refinement   -> Tables 4/5 (refinement effectiveness + 2D weakness)
  bench_partitioner  -> Table 1/2 + Fig 1 (end-to-end quality, breakdown)
  bench_kernels      -> kernel micro-benchmarks
  roofline           -> EXPERIMENTS.md §Roofline (needs dry-run artifacts)

``--quick`` trims suites/seeds for CI-speed runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="component|refinement|partitioner|kernels|roofline")
    args = ap.parse_args()

    from benchmarks import (bench_component, bench_kernels,
                            bench_partitioner, bench_refinement, roofline)

    sections = {
        "kernels": lambda: bench_kernels.main(quick=args.quick),
        "component": lambda: bench_component.main(quick=args.quick),
        "refinement": lambda: bench_refinement.main(quick=args.quick),
        "partitioner": lambda: bench_partitioner.main(quick=args.quick),
        "roofline": roofline.main,
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n== {name} ==", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # keep the harness going; report loudly
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"# {name} took {time.perf_counter()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
