import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: compile named variants of the three chosen cells
and record their roofline terms to artifacts/perf/<cell>__<variant>.json.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell commandr --variant mb2
    PYTHONPATH=src python -m benchmarks.hillclimb --all
"""
import argparse
import json
import time

VARIANTS = {
    # (arch, shape, tuning)
    "commandr": {
        "arch": "command-r-35b", "shape": "train_4k",
        "variants": {
            "baseline": {},
            "mb2": {"microbatches": 2},
            "mb2_zero1": {"microbatches": 2, "zero1": True},
            "mb4_zero1": {"zero1": True},
            "sp": {"config": {"seq_parallel": True}},
            "mb2_sp": {"microbatches": 2,
                       "config": {"seq_parallel": True}},
            "mb2_gcast": {"microbatches": 2,
                          "config": {"grad_cast": True}},
        },
    },
    "moonshot": {
        "arch": "moonshot-v1-16b-a3b", "shape": "train_4k",
        "variants": {
            "baseline": {},
            "grouped16": {"config": {"moe_groups": 16}},
            "grouped16_cf1": {"config": {"moe_groups": 16,
                                         "capacity_factor": 1.0}},
            "grouped16_zero1": {"config": {"moe_groups": 16}, "zero1": True},
            "sp": {"config": {"seq_parallel": True}},
            "gcast": {"config": {"grad_cast": True}},
        },
    },
    "meshgraphnet": {
        "arch": "meshgraphnet", "shape": "ogb_products",
        "variants": {
            "baseline": {},
            "part_h086": {"mode": "partitioned", "halo_frac": 0.86},
            "part_h045": {"mode": "partitioned", "halo_frac": 0.45},
            "part_h025": {"mode": "partitioned", "halo_frac": 0.25},
        },
    },
}


def run_variant(cell_name: str, variant: str, out_dir="artifacts/perf"):
    import jax

    from repro.configs import get_arch
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    spec = VARIANTS[cell_name]
    tuning = spec["variants"][variant]
    arch = get_arch(spec["arch"])
    mesh = make_production_mesh(multi_pod=False)
    rec = {"cell": cell_name, "arch": spec["arch"], "shape": spec["shape"],
           "variant": variant, "tuning": tuning, "status": "ok"}
    t0 = time.perf_counter()
    try:
        cell = build_cell(arch, spec["shape"], mesh, tuning=dict(tuning))
        with mesh:
            compiled = jax.jit(
                cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            ).lower(*cell.args).compile()
        ma = compiled.memory_analysis()
        rec["peak_gib"] = float(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30)
        cost = analyze_hlo(compiled.as_text())
        rec["cost"] = cost
        rec["meta"] = cell.meta
        # roofline terms
        PEAK, HBM, LINK = 197e12, 819e9, 50e9
        rec["compute_s"] = cost["flops"] / PEAK
        rec["memory_s"] = cost["bytes"] / HBM
        rec["collective_s"] = cost["collective_bytes"] / LINK
        rec["step_s"] = max(rec["compute_s"], rec["memory_s"],
                            rec["collective_s"])
        rec["bottleneck"] = max(
            ("compute", "memory", "collective"),
            key=lambda k: rec[f"{k}_s"])
        chips = 256
        rec["roofline_frac"] = (
            cell.meta["model_flops"] / chips / PEAK / rec["step_s"])
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        import traceback
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["wall_s"] = time.perf_counter() - t0
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{cell_name}__{variant}.json"),
              "w") as f:
        json.dump(rec, f, indent=1, default=str)
    if rec["status"] == "ok":
        print(f"[ok] {cell_name}/{variant}: step {rec['step_s']:.2f}s "
              f"(C {rec['compute_s']:.2f} M {rec['memory_s']:.2f} "
              f"X {rec['collective_s']:.2f}) bneck={rec['bottleneck']} "
              f"frac={rec['roofline_frac']:.2%} peak={rec['peak_gib']:.1f}GiB",
              flush=True)
    else:
        print(f"[error] {cell_name}/{variant}: {rec['error'][:200]}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        for cell_name, spec in VARIANTS.items():
            for variant in spec["variants"]:
                run_variant(cell_name, variant)
    else:
        run_variant(args.cell, args.variant or "baseline")


if __name__ == "__main__":
    main()
