"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    compute_s    = flops_per_device / PEAK_FLOPS
    memory_s     = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / LINK_BW
(all per-device: the dry-run HLO is the partitioned per-device program).

Hardware constants (TPU v5e-class, from the assignment):
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

flops/bytes come from the loop-corrected HLO cost model
(launch/hlo_cost.py) because XLA's cost_analysis counts while bodies once.
MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active params.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def load_cells(art_dir="artifacts/dryrun", mesh=None):
    cells = []
    pattern = os.path.join(art_dir, mesh or "*", "*.json")
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec):
    if rec["status"] != "ok":
        return None
    chips = CHIPS[rec["mesh"]]
    flops = rec["cost"]["flops"]            # per device (partitioned HLO)
    byts = rec["cost"]["bytes"]
    coll = rec["cost"]["collective_bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops_total = rec["meta"]["model_flops"]
    model_flops_dev = model_flops_total / chips
    useful_ratio = model_flops_dev / flops if flops else 0.0
    # roofline fraction: useful model flops per device / what the chips
    # could do in the bottleneck-bound step time
    frac = (model_flops_dev / PEAK_FLOPS) / step_s if step_s else 0.0
    return {
        "mesh": rec["mesh"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["meta"]["kind"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "step_s": step_s,
        "model_flops": model_flops_total,
        "hlo_flops_dev": flops,
        "useful_ratio": useful_ratio,
        "roofline_frac": frac,
        "peak_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
    }


def table(art_dir="artifacts/dryrun", mesh="pod16x16"):
    rows = []
    for rec in load_cells(art_dir, mesh):
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful (6ND/HLO) | roofline frac | peak GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2%} | {r['peak_gib']:.2f} |")
    return "\n".join(lines)


def main():
    for mesh in ("pod16x16",):
        rows = table(mesh=mesh)
        if not rows:
            print(f"# no artifacts for {mesh}; run repro.launch.dryrun first")
            continue
        print(f"# Roofline ({mesh}, single pod, per-device terms)")
        for r in sorted(rows, key=lambda r: -r["step_s"]):
            print(f"roofline/{r['arch']}/{r['shape']},{r['step_s']*1e6:.1f},"
                  f"bottleneck={r['bottleneck']};frac={r['roofline_frac']:.3f}")
    return 0


if __name__ == "__main__":
    main()
