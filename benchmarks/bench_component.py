"""Table 3 reproduction: Jetlp component effectiveness.

Paper: Geomean(Baseline Cutsize) / Geomean(Version Cutsize), versions =
baseline / +locks / +weak afterburner / +full afterburner / full Jetlp.
Paper values: 1.000 / 1.000 / 1.009 / 1.030 / 1.052.

We run each variant as the refinement inside the full multilevel
partitioner over the benchmark suite x seeds and report the same ratio.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core.partition import PartitionConfig, partition
from repro.core.refine import VARIANTS


def run(k: int = 16, lam: float = 0.03, seeds=(0,), quick: bool = False):
    names = list(SUITE) if not quick else ["grid", "rmat"]
    seeds = seeds if not quick else (0,)
    cuts = {v: [] for v in VARIANTS}
    t0 = time.perf_counter()
    for name in names:
        g = load(name)
        jax.clear_caches()
        for seed in seeds:
            for variant in VARIANTS:
                cfg = PartitionConfig(
                    k=k, lam=lam, seed=seed, variant=variant,
                    coarse_target=max(1024, 8 * k))
                res = partition(g, cfg)
                assert res.balanced, (name, variant, res.imbalance)
                cuts[variant].append(res.cut)
    gm = {v: float(np.exp(np.mean(np.log(np.asarray(cuts[v])))))
          for v in VARIANTS}
    base = gm["baseline"]
    rows = []
    for v in VARIANTS:
        rows.append((f"component/{v}", base / gm[v]))
    elapsed = time.perf_counter() - t0
    return rows, {"elapsed_s": elapsed, "geomeans": gm}


def main(quick=False):
    rows, info = run(quick=quick)
    print("# Table 3-style: Geomean(baseline cut) / Geomean(variant cut)")
    print("# paper: baseline 1.000, locks 1.000, weak_ab 1.009, "
          "full_ab 1.030, full 1.052")
    for name, ratio in rows:
        print(f"{name},{ratio:.4f}")
    return rows


if __name__ == "__main__":
    main()
