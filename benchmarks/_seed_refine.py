"""The seed's per-iteration-rebuild Jet loop, vendored verbatim for A/B
benchmarking (see bench_refinement.incremental_vs_rebuild's "seed" mode).

This is the pre-ConnState refinement driver: every iteration rebuilds
connectivity from scratch inside `jetlp_moves`/`jetrw_moves`/`jetrs_moves`
and recomputes cutsize and part sizes from the parts vector.  It runs
against the current core modules (their from-scratch entry points were kept
backward compatible), so timing it against `refine.jet_refine` isolates
exactly what the stateful refactor buys per iteration.  Not part of the
library surface; do not import outside benchmarks.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core import metrics
from repro.core import rebalance as rb
from repro.core.graph import Graph


VARIANTS = ("baseline", "locks", "weak_ab", "full_ab", "full")


def variant_flags(variant: str):
    """(use_ratio_filter, use_afterburner, use_locks) — Table 3 ablations."""
    return {
        "baseline": (False, False, False),
        "locks": (False, False, True),
        "weak_ab": (False, True, False),
        "full_ab": (True, True, False),
        "full": (True, True, True),
    }[variant]


def jetlp_moves(
    g: Graph,
    parts: jnp.ndarray,
    k: int,
    lock: jnp.ndarray,
    c: float,
    backend: str = "dense",
    variant: str = "full",
):
    """One unconstrained LP pass (Alg 4.2). Returns (move_mask, dest).

    First filter: Eq 4.3 ``-F(v) < floor(c * conn(v, P_s))  or  F(v) >= 0``.
    Second filter (afterburner): recompute gain against the approximate next
    state merged under ``ord`` (Eq 4.1), keep non-negative.  ``variant``
    selects the paper's §7.1.4 ablations (see ``variant_flags``).
    """
    use_ratio, use_ab, use_locks = variant_flags(variant)
    vmask = g.vertex_mask()
    q = cn.queries(g, parts, k, backend=backend)
    F = q.best_conn - q.conn_self  # gain of the best single move
    boundary = q.best_conn > 0

    if use_ratio:
        thr = jnp.floor(c * q.conn_self.astype(jnp.float32)).astype(jnp.int32)
        filter1 = (F >= 0) | (-F < thr)  # Eq 4.3 (strict <, floor rounding)
    else:
        filter1 = F >= 0
    X = vmask & boundary & filter1
    if use_locks:
        X = X & ~lock
    Pd = jnp.where(X, q.best_part, parts)
    if not use_ab:
        return X, Pd

    # Afterburner: per-edge approximate next state.
    u, v, w = g.adjncy, g.esrc, g.adjwgt
    Fu = F[u]
    Fv = F[v]
    # ord(u) < ord(v): u moves "first" iff higher priority gain, tie -> smaller id
    u_first = X[u] & ((Fu > Fv) | ((Fu == Fv) & (u < v)))
    pu = jnp.where(u_first, Pd[u], parts[u])
    contrib = w * (
        (pu == Pd[v]).astype(jnp.int32) - (pu == parts[v]).astype(jnp.int32)
    )
    F2 = jax.ops.segment_sum(
        jnp.where(g.edge_mask() & X[v], contrib, 0), v, num_segments=g.n_max
    )
    move = X & (F2 >= 0)
    return move, Pd


class RefineState(NamedTuple):
    parts: jnp.ndarray
    best_parts: jnp.ndarray
    best_cost: jnp.ndarray       # int32 cutsize of best
    best_maxsize: jnp.ndarray    # int32 max part weight of best
    best_balanced: jnp.ndarray   # bool
    lock: jnp.ndarray            # bool (N,) — last Jetlp move set
    since_best: jnp.ndarray      # int32 iterations since best improved
    weak_count: jnp.ndarray      # int32 consecutive weak rebalances
    it: jnp.ndarray              # int32 total iterations
    lp_iters: jnp.ndarray        # int32 (stats)
    rb_iters: jnp.ndarray        # int32 (stats)


@partial(
    jax.jit,
    static_argnames=(
        "k", "lam", "c", "backend", "patience", "max_iter", "b_max", "variant",
    ),
)
def jet_refine(
    g: Graph,
    parts0: jnp.ndarray,
    k: int,
    lam: float = 0.03,
    c: float = 0.75,
    phi: float = 0.999,
    backend: str = "dense",
    patience: int = 12,
    max_iter: int = 200,
    b_max: int = 2,
    variant: str = "full",
):
    """Alg 4.1. Returns (best_parts, stats dict)."""
    W = g.total_vweight()
    limit = metrics.size_limit(W, k, lam)
    vmask = g.vertex_mask()
    parts0 = jnp.where(vmask, parts0, k).astype(jnp.int32)

    sizes0 = metrics.part_sizes(g, parts0, k)
    cost0 = metrics.cutsize(g, parts0)
    max0 = jnp.max(sizes0)
    st = RefineState(
        parts=parts0,
        best_parts=parts0,
        best_cost=cost0.astype(jnp.int32),
        best_maxsize=max0.astype(jnp.int32),
        best_balanced=max0 <= limit,
        lock=jnp.zeros((g.n_max,), bool),
        since_best=jnp.int32(0),
        weak_count=jnp.int32(0),
        it=jnp.int32(0),
        lp_iters=jnp.int32(0),
        rb_iters=jnp.int32(0),
    )

    def cond(st: RefineState):
        return (st.since_best < patience) & (st.it < max_iter)

    def body(st: RefineState):
        sizes = metrics.part_sizes(g, st.parts, k)
        balanced = jnp.max(sizes) <= limit

        def do_lp(_):
            move, dest = jetlp_moves(g, st.parts, k, st.lock, c, backend, variant)
            parts2 = jnp.where(move, dest, st.parts)
            return parts2, move, jnp.int32(0), jnp.int32(1), jnp.int32(0)

        def do_rb(_):
            def weak(_):
                move, dest = rb.jetrw_moves(g, st.parts, k, lam, backend)
                return move, dest

            def strong(_):
                move, dest = rb.jetrs_moves(g, st.parts, k, lam, backend)
                return move, dest

            move, dest = jax.lax.cond(st.weak_count < b_max, weak, strong, None)
            parts2 = jnp.where(move, dest, st.parts)
            # rebalancing does not touch lock state (paper §4.1.3)
            return parts2, st.lock, st.weak_count + 1, jnp.int32(0), jnp.int32(1)

        parts2, lock2, weak2, dlp, drb = jax.lax.cond(balanced, do_lp, do_rb, None)

        cost2 = metrics.cutsize(g, parts2).astype(jnp.int32)
        sizes2 = metrics.part_sizes(g, parts2, k)
        max2 = jnp.max(sizes2).astype(jnp.int32)
        bal2 = max2 <= limit

        # Best tracking (Alg 4.1 lines 16-23, fixed so a balanced partition
        # always supersedes an unbalanced best — see DESIGN.md §6).
        take_bal = bal2 & (~st.best_balanced | (cost2 < st.best_cost))
        significant = bal2 & (
            ~st.best_balanced
            | (cost2.astype(jnp.float32) < phi * st.best_cost.astype(jnp.float32))
        )
        take_imb = (~bal2) & (~st.best_balanced) & (max2 < st.best_maxsize)
        take = take_bal | take_imb
        reset = significant | take_imb

        return RefineState(
            parts=parts2,
            best_parts=jnp.where(take, parts2, st.best_parts),
            best_cost=jnp.where(take, cost2, st.best_cost),
            best_maxsize=jnp.where(take, max2, st.best_maxsize),
            best_balanced=st.best_balanced | bal2,
            lock=lock2,
            since_best=jnp.where(reset, jnp.int32(0), st.since_best + 1),
            weak_count=jnp.where(bal2, jnp.int32(0), weak2),
            it=st.it + 1,
            lp_iters=st.lp_iters + dlp,
            rb_iters=st.rb_iters + drb,
        )

    st = jax.lax.while_loop(cond, body, st)
    stats = {
        "iterations": st.it,
        "lp_iters": st.lp_iters,
        "rb_iters": st.rb_iters,
        "best_cost": st.best_cost,
        "best_maxsize": st.best_maxsize,
        "best_balanced": st.best_balanced,
    }
    return st.best_parts, stats
