"""Serving-path benchmark + CI smoke: the §11 micro-batching server.

Replays a mixed-shape, mixed-k request burst through an in-process
:class:`~repro.launch.partition_serve.PartitionServer` and emits
``BENCH_serve.json`` with p50/p95 latency, throughput, the
batch-occupancy histogram, and compile-cache hit counts.

``--smoke`` is the CI serving gate.  Per backend it asserts:

* every coalesced response is bit-identical to its standalone
  ``partition()`` run (``run_workload(verify=True)``);
* at least one dispatched bucket had mixed occupancy (>= 2 real lanes
  holding different true sizes — the workload pairs near-sized grids on
  purpose);
* exactly one ``uncoarsen_level_fleet`` executable per (rung, k)
  signature — the fixed-lanes discipline keeps the batch axis out of the
  compile key;
* after the AOT warmup pass, replaying the workload compiles ZERO new
  executables (and, when a persistent compile cache is wired, zero
  compilation-cache misses).

The committed ``BENCH_serve.json`` doubles as the CI serving baseline:
``bench_partitioner.py --check-baseline`` gates fresh throughput and
batch occupancy against it using the ``baseline_tolerance`` /
``throughput_tolerance`` tags.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.core.partition import PartitionConfig

# occupancy is structural (same workload -> same batches) so the default
# cut-style tolerance applies; throughput is wall-clock on shared CI
# runners, so its gate only catches order-of-magnitude collapses
BASELINE_TOLERANCE = 0.25
THROUGHPUT_TOLERANCE = 0.9

SMOKE_SPEC = {
    # near-sized grids: 13x13 and 12x12 round to one capacity rung on the
    # (192, 1280) serve ladder (mixed-occupancy bucket); 6x6 lands in its
    # own bucket behind a filler lane
    "families": [{"graph": "grid", "size": 13},
                 {"graph": "grid", "size": 12},
                 {"graph": "grid", "size": 6}],
    "ks": [2, 4],
    "count": 12,
    "rate_rps": 2000.0,   # burst: arrivals well inside one window
    "trials": 1,
    "seed": 0,
}


def _smoke_serve_cfg(backend: str, compile_cache=None):
    from repro.launch.partition_serve import ServeConfig

    pcfg = PartitionConfig(k=4, backend=backend, coarse_target=32,
                           max_iter=40, patience=4)
    # window >> the burst's arrival span, so a slow CI runner still
    # coalesces the whole burst into one deterministic batch
    return ServeConfig(ladder_n=192, ladder_m=1280, window_s=0.025, lanes=2,
                       partition=pcfg, compile_cache=compile_cache)


def serve_smoke(backends=("dense", "sorted", "ell"),
                json_path="BENCH_serve.json", compile_cache=None):
    """The CI serving gate; returns the (written) report dict."""
    from repro.launch.partition_serve import cache_stats
    from repro.launch.serve_cli import run_workload

    # merge into an existing report (bench_partitioner smoke convention):
    # backends can be run in separate invocations into one gate-able JSON
    try:
        with open(json_path) as f:
            report = json.load(f)
    except (OSError, ValueError):
        report = {}
    for backend in backends:
        # fresh jit cache per backend: the executable-count gates compare
        # cache-size deltas against signature counts, which an earlier
        # in-process bench (check_baseline runs the partitioner smokes
        # first) would contaminate — same discipline as fleet_ab
        jax.clear_caches()
        cache0 = cache_stats().snapshot()
        rep = run_workload(_smoke_serve_cfg(backend, compile_cache),
                           SMOKE_SPEC, warmup=True, verify=True)
        occ = {int(kk): vv
               for kk, vv in rep["server"]["occupancy_hist"].items()}

        # gate: mixed occupancy actually happened — some bucket held >= 2
        # real members of genuinely different sizes (not two copies of
        # one family that merely shared a rung)
        mixed = any(
            b["real"] >= 2 and len(set(b["member_n_max"])) >= 2
            for d in rep["dispatch_buckets"] for b in d
        )
        if not mixed:
            raise AssertionError(
                f"serve smoke [{backend}]: no dispatched bucket held >= 2 "
                f"differently-sized members (occupancy {occ}) — the "
                "near-sized grids must share a rung"
            )
        # gate: the replay compiled nothing after warmup
        if rep["post_warmup_new_executables"] != 0:
            raise AssertionError(
                f"serve smoke [{backend}]: replay compiled "
                f"{rep['post_warmup_new_executables']} new "
                "uncoarsen_level_fleet executables after warmup — the AOT "
                "grid must cover the workload"
            )
        # gate: one executable per (rung, k) signature — the AOT grid
        # compiled each of its signatures exactly once, and the replay's
        # signature set stayed inside the grid
        if rep["warmup"]["new_executables"] != rep["warmup_signatures"]:
            raise AssertionError(
                f"serve smoke [{backend}]: warmup compiled "
                f"{rep['warmup']['new_executables']} executables for "
                f"{rep['warmup_signatures']} (rung, k) signatures — "
                "batching must not multiply compiles"
            )
        if not rep["replay_covered_by_warmup"]:
            raise AssertionError(
                f"serve smoke [{backend}]: the replay hit signatures "
                "outside the warmup grid — the AOT pass must cover the "
                "workload's (rung, k) set"
            )
        cache_delta = {
            kk: vv - cache0.get(kk, 0)
            for kk, vv in cache_stats().snapshot().items()
        }
        report[backend] = {
            "requests": rep["requests"],
            "bit_identical": rep["bit_identical"],
            "throughput_rps": rep["throughput_rps"],
            "p50_latency_ms": rep["p50_latency_ms"],
            "p95_latency_ms": rep["p95_latency_ms"],
            "occupancy_hist": rep["server"]["occupancy_hist"],
            "mean_occupancy": rep["server"]["mean_occupancy"],
            "dispatches": rep["server"]["dispatches"],
            "filler_lanes": rep["server"]["filler_lanes"],
            "serve_signatures": rep["serve_signatures"],
            "warmup_s": rep["warmup"]["warmup_s"],
            "warmup_executables": rep["warmup"]["new_executables"],
            "post_warmup_new_executables":
                rep["post_warmup_new_executables"],
            "compile_cache_events": cache_delta,
        }
        print(f"[serve-smoke:{backend}] {rep['requests']} req, "
              f"p50 {rep['p50_latency_ms']:.1f} ms, "
              f"occupancy {rep['server']['occupancy_hist']}, "
              f"{rep['serve_signatures']} signatures, "
              f"0 post-warmup compiles")

    report["baseline_tolerance"] = BASELINE_TOLERANCE
    report["throughput_tolerance"] = THROUGHPUT_TOLERANCE
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"-> {json_path}")
    return report


def compare_serve_baseline(fresh, baseline, tolerance=None):
    """Serving-path regression check (mirrors ``compare_baseline``):
    per-backend mean batch occupancy may not drop by more than the
    baseline's ``baseline_tolerance`` (occupancy is structural under a
    fixed workload), throughput by more than ``throughput_tolerance``
    (loose — CI wall clocks are noisy), and bit-equivalence plus the
    zero-post-warmup-compile property must still hold.  Returns
    human-readable regression strings (empty == gate passes)."""
    tol = tolerance if tolerance is not None else \
        baseline.get("baseline_tolerance", BASELINE_TOLERANCE)
    tput_tol = baseline.get("throughput_tolerance", THROUGHPUT_TOLERANCE)
    backends = [kk for kk in baseline
                if isinstance(baseline[kk], dict) and "mean_occupancy"
                in baseline[kk]]
    bad = []
    common = [b for b in backends if b in fresh]
    if backends and not common:
        bad.append(
            "serve: no backend section shared between fresh report and "
            "baseline — the serving gate would pass vacuously; regenerate "
            "BENCH_serve.json"
        )
    for b in common:
        fb, bb = fresh[b], baseline[b]
        if not fb.get("bit_identical", False):
            bad.append(f"serve/{b}: responses no longer bit-identical to "
                       "standalone partition()")
        if fb.get("post_warmup_new_executables", 0) != 0:
            bad.append(
                f"serve/{b}: {fb['post_warmup_new_executables']} "
                "executables compiled after warmup (baseline: 0)"
            )
        floor = bb["mean_occupancy"] * (1.0 - tol)
        if fb["mean_occupancy"] < floor:
            bad.append(
                f"serve/{b}: mean batch occupancy {fb['mean_occupancy']:.2f}"
                f" fell below baseline {bb['mean_occupancy']:.2f} by more "
                f"than {100 * tol:.0f}%"
            )
        tput_floor = bb["throughput_rps"] * (1.0 - tput_tol)
        if fb["throughput_rps"] < tput_floor:
            bad.append(
                f"serve/{b}: throughput {fb['throughput_rps']:.2f} rps "
                f"fell below {tput_floor:.2f} (baseline "
                f"{bb['throughput_rps']:.2f} - {100 * tput_tol:.0f}%)"
            )
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI serving gate: tiny burst, all gates on")
    ap.add_argument("--backends", default="dense,sorted,ell",
                    help="comma-separated backend list for --smoke")
    ap.add_argument("--compile-cache", default=None,
                    help="JAX persistent compilation cache directory")
    ap.add_argument("--json", default="BENCH_serve.json")
    a = ap.parse_args()
    if not a.smoke:
        ap.error("only --smoke is implemented; use serve_cli for ad-hoc "
                 "replays")
    serve_smoke(backends=tuple(a.backends.split(",")), json_path=a.json,
                compile_cache=a.compile_cache)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
