"""Table 4/5-style: refinement effectiveness — Jet vs size-constrained LP
on identical inputs (same hierarchy, same initial partition), plus the
paper's §7.1.2 2D-vs-3D weakness measurement (grid vs cube).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import jax
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import metrics, refine
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import PartitionConfig, partition


def _balanced_random(g, k, seed):
    rng = np.random.default_rng(seed)
    p = np.full(g.n_max, k, dtype=np.int32)
    n = int(g.n)
    perm = rng.permutation(n)
    p[perm] = np.arange(n) % k
    return jnp.asarray(p)


def run(k: int = 16, lam: float = 0.03, seeds=(0, 1), quick=False):
    names = list(SUITE) if not quick else ["grid", "cube"]
    seeds = seeds if not quick else (0,)
    rows = []
    detail = {}
    for name in names:
        g = load(name)
        jax.clear_caches()
        ratios = []
        times = []
        for seed in seeds:
            parts0 = _balanced_random(g, k, seed)
            t0 = time.perf_counter()
            jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
            t_jet = time.perf_counter() - t0
            t0 = time.perf_counter()
            clp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam,
                                                 iters=30)
            t_clp = time.perf_counter() - t0
            jc = int(metrics.cutsize(g, jet_parts))
            cc = int(metrics.cutsize(g, clp_parts))
            ratios.append(cc / max(jc, 1))  # >1 -> Jet better
            times.append(t_clp / max(t_jet, 1e-9))
        r = float(np.exp(np.mean(np.log(ratios))))
        rows.append((f"refine_effect/{name}", r))
        detail[name] = {"cut_ratio_clp_over_jet": r,
                        "time_ratio": float(np.mean(times))}
    return rows, detail


def weakness_2d_vs_3d(k: int = 16, lam: float = 0.03, seeds=(0,)):
    """Paper §7.1.2: Jet's refinement advantage shrinks on large-diameter 2D
    meshes vs 3D.  We measure (CLP cut / Jet cut) on grid vs cube — the
    paper's mechanism predicts a smaller ratio on the 2D grid."""
    out = {}
    for name in ("grid", "cube"):
        g = load(name)
        ratios = []
        for seed in seeds:
            parts0 = _balanced_random(g, k, seed)
            jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
            clp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam,
                                                 iters=30)
            ratios.append(int(metrics.cutsize(g, clp_parts))
                          / max(int(metrics.cutsize(g, jet_parts)), 1))
        out[name] = float(np.exp(np.mean(np.log(ratios))))
    return out


def main(quick=False):
    rows, detail = run(quick=quick)
    print("# Jet vs constrained LP on identical inputs "
          "(ratio > 1 means Jet is better)")
    for name, ratio in rows:
        print(f"{name},{ratio:.4f}")
    if not quick:
        w = weakness_2d_vs_3d()
        print(f"weakness/grid_2d,{w['grid']:.4f}")
        print(f"weakness/cube_3d,{w['cube']:.4f}")
        print(f"# paper predicts grid ratio < cube ratio "
              f"(2D weakness): {w['grid']:.3f} vs {w['cube']:.3f}")
    return rows


if __name__ == "__main__":
    main()
