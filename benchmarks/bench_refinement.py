"""Table 4/5-style: refinement effectiveness — Jet vs size-constrained LP
on identical inputs (same hierarchy, same initial partition), plus the
paper's §7.1.2 2D-vs-3D weakness measurement (grid vs cube), plus the
stateful-refinement A/B: incremental ConnState updates (Alg 4.4, default)
vs a full rebuild every iteration (``rebuild_every=1``).  Results land in
``BENCH_refinement.json`` with per-iteration timings for both modes.
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import jax
import numpy as np

from benchmarks.graphs_suite import SUITE, load
from repro.core import metrics, refine
from repro.core.lp_baseline import constrained_lp_refine
from repro.core.partition import PartitionConfig, partition


def _balanced_random(g, k, seed):
    rng = np.random.default_rng(seed)
    p = np.full(g.n_max, k, dtype=np.int32)
    n = int(g.n)
    perm = rng.permutation(n)
    p[perm] = np.arange(n) % k
    return jnp.asarray(p)


def run(k: int = 16, lam: float = 0.03, seeds=(0, 1), quick=False):
    names = list(SUITE) if not quick else ["grid", "cube"]
    seeds = seeds if not quick else (0,)
    rows = []
    detail = {}
    for name in names:
        g = load(name)
        jax.clear_caches()
        ratios = []
        times = []
        for seed in seeds:
            parts0 = _balanced_random(g, k, seed)
            t0 = time.perf_counter()
            jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
            t_jet = time.perf_counter() - t0
            t0 = time.perf_counter()
            clp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam,
                                                 iters=30)
            t_clp = time.perf_counter() - t0
            jc = int(metrics.cutsize(g, jet_parts))
            cc = int(metrics.cutsize(g, clp_parts))
            ratios.append(cc / max(jc, 1))  # >1 -> Jet better
            times.append(t_clp / max(t_jet, 1e-9))
        r = float(np.exp(np.mean(np.log(ratios))))
        rows.append((f"refine_effect/{name}", r))
        detail[name] = {"cut_ratio_clp_over_jet": r,
                        "time_ratio": float(np.mean(times))}
    return rows, detail


def weakness_2d_vs_3d(k: int = 16, lam: float = 0.03, seeds=(0,)):
    """Paper §7.1.2: Jet's refinement advantage shrinks on large-diameter 2D
    meshes vs 3D.  We measure (CLP cut / Jet cut) on grid vs cube — the
    paper's mechanism predicts a smaller ratio on the 2D grid."""
    out = {}
    for name in ("grid", "cube"):
        g = load(name)
        ratios = []
        for seed in seeds:
            parts0 = _balanced_random(g, k, seed)
            jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
            clp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam,
                                                 iters=30)
            ratios.append(int(metrics.cutsize(g, clp_parts))
                          / max(int(metrics.cutsize(g, jet_parts)), 1))
        out[name] = float(np.exp(np.mean(np.log(ratios))))
    return out


def incremental_vs_rebuild(k: int = 16, lam: float = 0.03, quick=False,
                           modes=("incremental", "rebuild", "seed"),
                           backend: str = "dense"):
    """Per-iteration refinement cost, three ways:

    * ``incremental`` — threaded ConnState advanced by Alg 4.4 deltas
      (``rebuild_every=0``, the default path);
    * ``rebuild``     — same threaded state, fully rebuilt every iteration
      (``rebuild_every=1``, the escape hatch);
    * ``seed``        — the vendored pre-ConnState loop
      (benchmarks/_seed_refine.py), which rebuilds connectivity inside every
      move function and recomputes sizes/cut from the parts vector.

    All modes walk bit-identical trajectories, so iteration counts and cuts
    must match — the delta is pure per-iteration cost.  Two scenarios per
    graph: ``lp`` (balanced random start, Jetlp-dominated) and ``rb``
    (everything in part 0, rebalance-dominated — where the seed loop paid
    for three connectivity builds per iteration).
    """
    from benchmarks import _seed_refine

    names = ["grid", "cube"] if quick else list(SUITE)

    if backend == "ell":
        # the pre-ConnState loop cannot trace csr_to_ell under jit (its max
        # degree was a traced value) — the stateful refactor is what made
        # the ELL backend usable inside the refinement loop at all
        modes = tuple(m for m in modes if m != "seed")

    def run_mode(g, parts0, mode):
        if mode == "seed":
            fn = lambda: _seed_refine.jet_refine(g, parts0, k, lam=lam,
                                                 backend=backend)
        else:
            re_every = {"incremental": 0, "rebuild": 1}[mode]
            fn = lambda: refine.jet_refine(g, parts0, k, lam=lam,
                                           backend=backend,
                                           rebuild_every=re_every)
        p, _ = fn()  # compile
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        p, stats = fn()
        jax.block_until_ready(p)
        dt = time.perf_counter() - t0
        iters = int(stats["iterations"])
        return {
            "total_s": dt,
            "iterations": iters,
            "us_per_iter": dt / max(iters, 1) * 1e6,
            "cut": int(stats["best_cost"]),
        }

    out = {}
    for name in names:
        g = load(name)
        jax.clear_caches()
        scenarios = {
            "lp": _balanced_random(g, k, 0),
            "rb": jnp.where(g.vertex_mask(), 0, k).astype(jnp.int32),
        }
        rec = {}
        for scen, parts0 in scenarios.items():
            srec = {m: run_mode(g, parts0, m) for m in modes}
            cuts = {srec[m]["cut"] for m in modes}
            assert len(cuts) == 1, f"modes diverged on {name}/{scen}: {srec}"
            base = srec.get("seed") or srec.get("rebuild")
            if base is not None and "incremental" in srec:
                srec["speedup_per_iter"] = (
                    base["us_per_iter"]
                    / max(srec["incremental"]["us_per_iter"], 1e-9)
                )
            rec[scen] = srec
        out[name] = rec
    return out


def main(quick=False, modes=("incremental", "rebuild", "seed"),
         json_path="BENCH_refinement.json"):
    rows, detail = run(quick=quick)
    print("# Jet vs constrained LP on identical inputs "
          "(ratio > 1 means Jet is better)")
    for name, ratio in rows:
        print(f"{name},{ratio:.4f}")
    report = {"refine_effect": detail}
    if not quick:
        w = weakness_2d_vs_3d()
        print(f"weakness/grid_2d,{w['grid']:.4f}")
        print(f"weakness/cube_3d,{w['cube']:.4f}")
        print(f"# paper predicts grid ratio < cube ratio "
              f"(2D weakness): {w['grid']:.3f} vs {w['cube']:.3f}")
        report["weakness_2d_vs_3d"] = w
    report["incremental_vs_rebuild"] = {}
    for backend in ("dense", "ell"):
        ivr = incremental_vs_rebuild(quick=quick, modes=modes,
                                     backend=backend)
        report["incremental_vs_rebuild"][backend] = ivr
        for name, rec in ivr.items():
            for scen, srec in rec.items():
                for mode, mrec in srec.items():
                    if mode == "speedup_per_iter":
                        print(f"refine_iter/{backend}/{name}/{scen}/speedup,"
                              f"{mrec:.3f}")
                    else:
                        print(f"refine_iter/{backend}/{name}/{scen}/{mode},"
                              f"{mrec['us_per_iter']:.1f},us_per_iter")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--incremental", action="store_true",
                     help="time only the incremental (rebuild_every=0) mode")
    grp.add_argument("--rebuild", action="store_true",
                     help="time only the per-iteration-rebuild mode")
    ap.add_argument("--json", default="BENCH_refinement.json",
                    help="output JSON path ('' to disable)")
    args = ap.parse_args()
    if args.incremental:
        modes = ("incremental",)
    elif args.rebuild:
        modes = ("rebuild",)
    else:
        modes = ("incremental", "rebuild", "seed")
    main(quick=args.quick, modes=modes, json_path=args.json)
