"""Shared test configuration.

* Makes the repo root importable so tests can exercise the ``benchmarks``
  package (the CI quality gate) without installing anything.
* ``JET_TEST_BACKEND`` env filter: when set to ``dense`` / ``sorted`` /
  ``ell``, every test parametrized over a connectivity ``backend`` keeps
  only the matching parametrization (unparametrized tests always run).
  CI matrixes its tier-1 job over this variable so the three backends run
  in parallel lanes instead of serially in one.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

_BACKENDS = ("dense", "sorted", "ell")


def pytest_collection_modifyitems(config, items):
    backend = os.environ.get("JET_TEST_BACKEND")
    if not backend:
        return
    if backend not in _BACKENDS:
        raise ValueError(
            f"JET_TEST_BACKEND={backend!r} must be one of {_BACKENDS}"
        )
    kept, deselected = [], []
    for item in items:
        callspec = getattr(item, "callspec", None)
        param = callspec.params.get("backend") if callspec else None
        if param is not None and param != backend:
            deselected.append(item)
        else:
            kept.append(item)
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = kept
