"""Jetlp / Jetr / full Jet refinement behaviour tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, rebalance, refine
from repro.core.graph import build_csr_host
from repro.core.partition import PartitionConfig, partition, refine_only
from repro.data import graphs as gen


def _rand_parts(g, k, seed=0):
    rng = np.random.default_rng(seed)
    p = np.full(g.n_max, k, dtype=np.int32)
    p[: int(g.n)] = rng.integers(0, k, int(g.n))
    return jnp.asarray(p)


def test_slot_values():
    loss = jnp.asarray([-5, -1, 0, 1, 2, 3, 4, 7, 8, 1024])
    s = np.asarray(rebalance.slot(loss))
    assert list(s) == [0, 0, 1, 2, 3, 3, 4, 4, 5, 12]


def test_jetlp_improves_cut():
    g = gen.grid2d(16, 16)
    k = 4
    parts = _rand_parts(g, k)
    lock = jnp.zeros((g.n_max,), bool)
    cut0 = int(metrics.cutsize(g, parts))
    move, dest = refine.jetlp_moves(g, parts, k, lock, c=0.25)
    parts2 = jnp.where(move, dest, parts)
    cut1 = int(metrics.cutsize(g, parts2))
    assert cut1 < cut0


def test_jetlp_respects_locks():
    g = gen.grid2d(16, 16)
    k = 4
    parts = _rand_parts(g, k)
    lock = jnp.ones((g.n_max,), bool)
    move, _ = refine.jetlp_moves(g, parts, k, lock, c=0.25)
    assert int(jnp.sum(move.astype(jnp.int32))) == 0


@pytest.mark.parametrize("mode", ["weak", "strong"])
def test_rebalance_reduces_oversize(mode):
    g = gen.grid2d(20, 20)  # 400 vertices
    k = 4
    lam = 0.03
    # pathological: everything in part 0
    parts = jnp.where(g.vertex_mask(), 0, k).astype(jnp.int32)
    fn = rebalance.jetrw_moves if mode == "weak" else rebalance.jetrs_moves
    move, dest = fn(g, parts, k, lam)
    parts2 = jnp.where(move, dest, parts)
    sizes0 = np.asarray(metrics.part_sizes(g, parts, k))
    sizes2 = np.asarray(metrics.part_sizes(g, parts2, k))
    assert sizes2.max() < sizes0.max()
    # destinations are real parts
    d = np.asarray(dest)[np.asarray(move)]
    assert d.min() >= 0 and d.max() < k


def test_strong_rebalance_balances_in_one_shot():
    g = gen.grid2d(20, 20)
    k = 4
    lam = 0.10
    parts = jnp.where(g.vertex_mask(), 0, k).astype(jnp.int32)
    move, dest = rebalance.jetrs_moves(g, parts, k, lam)
    parts2 = jnp.where(move, dest, parts)
    W = g.total_vweight()
    sizes2 = metrics.part_sizes(g, parts2, k)
    assert bool(metrics.is_balanced(sizes2, W, k, lam))


@pytest.mark.parametrize("backend", ["dense", "sorted"])
def test_jet_refine_balances_and_improves(backend):
    g = gen.suite_graph("geo_4k")
    k = 8
    lam = 0.03
    parts0 = _rand_parts(g, k, seed=3)
    cut0 = int(metrics.cutsize(g, parts0))
    parts, stats = refine.jet_refine(g, parts0, k, lam=lam, backend=backend)
    W = g.total_vweight()
    sizes = metrics.part_sizes(g, parts, k)
    assert bool(metrics.is_balanced(sizes, W, k, lam)), "output unbalanced"
    cut1 = int(metrics.cutsize(g, parts))
    assert cut1 < cut0 * 0.9, f"barely improved: {cut0} -> {cut1}"
    # all real vertices have real parts; pads ghost
    p = np.asarray(parts)
    assert p[: int(g.n)].max() < k
    assert np.all(p[int(g.n):] == k)


def test_jet_refine_from_unbalanced_start():
    g = gen.grid2d(24, 24)
    k = 6
    lam = 0.05
    parts0 = jnp.where(g.vertex_mask(), 0, k).astype(jnp.int32)
    parts, stats = refine.jet_refine(g, parts0, k, lam=lam)
    W = g.total_vweight()
    sizes = metrics.part_sizes(g, parts, k)
    assert bool(metrics.is_balanced(sizes, W, k, lam))
    assert int(stats["rb_iters"]) >= 1


@pytest.mark.parametrize("variant", list(refine.VARIANTS))
def test_refine_variants_run(variant):
    g = gen.grid2d(12, 12)
    k = 4
    parts0 = _rand_parts(g, k, seed=1)
    parts, _ = refine.jet_refine(g, parts0, k, lam=0.05, variant=variant)
    W = g.total_vweight()
    sizes = metrics.part_sizes(g, parts, k)
    assert bool(metrics.is_balanced(sizes, W, k, 0.05))


def test_full_partition_pipeline():
    g = gen.suite_graph("rmat_12")
    cfg = PartitionConfig(k=8, lam=0.03, coarse_target=256)
    res = partition(g, cfg)
    assert res.balanced, f"imbalance {res.imbalance}"
    assert res.cut > 0
    assert res.levels >= 2
    # compare against a random partition: multilevel must be far better
    rng = np.random.default_rng(0)
    rand = jnp.asarray(
        np.where(np.arange(g.n_max) < int(g.n), rng.integers(0, 8, g.n_max), 8)
        .astype(np.int32)
    )
    rand_cut = int(metrics.cutsize(g, rand))
    # RMAT is an expander: min cuts are genuinely large; still must beat random
    assert res.cut < 0.6 * rand_cut, f"cut {res.cut} vs random {rand_cut}"


def test_full_partition_quality_grid():
    # structured grid: quality is checkable against the geometric optimum
    g = gen.grid2d(64, 64)
    res = partition(g, PartitionConfig(k=8, lam=0.03, coarse_target=256))
    assert res.balanced
    # 4x2 blocks of 16x32 cost 256; accept anything within 1.5x of optimal
    assert res.cut <= 384, f"grid cut {res.cut} far from optimal 256"


def test_refine_only_mode():
    g = gen.grid2d(32, 32)
    k = 4
    parts0 = _rand_parts(g, k, seed=7)
    cfg = PartitionConfig(k=k, lam=0.03)
    res = refine_only(g, parts0, cfg)
    assert res.balanced
    assert res.cut < int(metrics.cutsize(g, parts0))


def test_weighted_vertices_balance():
    # non-uniform vertex weights
    g0 = gen.grid2d(16, 16)
    from repro.core.graph import graph_to_host

    n, edges, ew, _ = graph_to_host(g0)
    rng = np.random.default_rng(5)
    vw = rng.integers(1, 5, n)
    g = build_csr_host(n, edges, ew, vw)
    k = 4
    lam = 0.10
    parts0 = _rand_parts(g, k, seed=2)
    parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
    W = g.total_vweight()
    sizes = metrics.part_sizes(g, parts, k)
    assert bool(metrics.is_balanced(sizes, W, k, lam))
