"""Optimizer, compression, checkpoint/restart, fault-tolerant loop tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as synth
from repro.models import transformer as tf
from repro.optim import adamw, compression
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop


def _toy_setup(tmp):
    cfg = tf.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      head_dim=16, d_ff=64, vocab=64, remat=False,
                      dtype="float32", attn_chunk=16)

    def make_params():  # train_step donates params; re-init per run
        return tf.init_params(cfg, jax.random.key(0))

    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    data = synth.lm_batches(cfg.vocab, batch=4, seq=16, seed=0)
    step = train_loop.build_train_step(
        lambda p, b: tf.loss_fn(cfg, p, b), opt_cfg)
    return cfg, make_params, opt_cfg, data, step


def test_adamw_converges_quadratic():
    p = {"x": jnp.asarray([5.0, -3.0])}
    st = adamw.init_state(p)
    cfg = adamw.AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, schedule="const")
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        p, st, _ = adamw.apply_updates(cfg, p, g, st)
    assert float(jnp.max(jnp.abs(p["x"]))) < 2e-2


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_compression_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))}
    err = compression.init_error(g)
    # accumulated dequantized grads with error feedback track the true sum
    total_true = np.zeros((64, 64), np.float32)
    total_deq = np.zeros((64, 64), np.float32)
    for i in range(20):
        gi = {"w": jnp.asarray(
            rng.standard_normal((64, 64)).astype(np.float32))}
        q, s, err = compression.compress(gi, err)
        deq = compression.decompress(q, s)
        total_true += np.asarray(gi["w"])
        total_deq += np.asarray(deq["w"])
    # error feedback keeps the running sum within one quantization step
    resid = np.abs(total_true - total_deq).max()
    assert resid < 0.1, resid
    assert compression.compressed_bytes(g) < compression.raw_bytes(g) / 3.9


def test_checkpoint_atomic_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree, extra={"note": "hi"})
    assert ckpt.latest_step(d) == 7
    got = ckpt.restore(d, 7, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    man = ckpt.read_manifest(d, 7)
    assert man["extra"]["note"] == "hi"


def test_loop_checkpoint_restart_bitwise(tmp_path):
    """Train 30 straight vs 15 + crash + resume 15: same final params."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    cfg, make_params, opt_cfg, _, step = _toy_setup(tmp_path)

    def fresh_data():
        return synth.lm_batches(cfg.vocab, batch=4, seq=16, seed=0)

    # continuous run
    lc = train_loop.TrainLoopConfig(
        total_steps=30, ckpt_every=15, ckpt_dir=d1, resume=False)
    p0 = make_params()
    st = train_loop.TrainState(p0, adamw.init_state(p0), 0)
    final_a = train_loop.run(lc, st, step, fresh_data(), log=lambda *a: None)

    # crash at 15, then resume. Data iterator restarts deterministically at
    # the checkpoint boundary (seeded stream + step-aligned ckpt_every).
    lc2 = train_loop.TrainLoopConfig(
        total_steps=30, ckpt_every=15, ckpt_dir=d2, resume=True,
        fail_at_step=15)
    p1 = make_params()
    st2 = train_loop.TrainState(p1, adamw.init_state(p1), 0)
    with pytest.raises(train_loop.SimulatedFailure):
        train_loop.run(lc2, st2, step, fresh_data(), log=lambda *a: None)
    # restart: skip the first 15 batches to realign the stream
    data2 = fresh_data()
    for _ in range(15):
        next(data2)
    p2 = make_params()
    st3 = train_loop.TrainState(p2, adamw.init_state(p2), 0)
    lc3 = train_loop.TrainLoopConfig(
        total_steps=30, ckpt_every=15, ckpt_dir=d2, resume=True)
    final_b = train_loop.run(lc3, st3, step, data2, log=lambda *a: None)

    for a, b in zip(jax.tree.leaves(final_a.params),
                    jax.tree.leaves(final_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_compressed_grads_still_learn(tmp_path):
    cfg, make_params, opt_cfg, data, _ = _toy_setup(tmp_path)
    step = train_loop.build_train_step(
        lambda p, b: tf.loss_fn(cfg, p, b), opt_cfg, compress=True)
    lc = train_loop.TrainLoopConfig(
        total_steps=25, ckpt_every=100, ckpt_dir=str(tmp_path / "c"),
        resume=False, compress_grads=True)
    params = make_params()
    st = train_loop.TrainState(params, adamw.init_state(params), 0)
    losses = []
    final = train_loop.run(lc, st, step, data,
                           log=lambda m: losses.append(m))
    msgs = [m for m in losses if isinstance(m, str) and "loss" in m]
    first = float(msgs[0].split("loss ")[1].split(" ")[0])
    last = float(msgs[-1].split("loss ")[1].split(" ")[0])
    assert last < first, (first, last)


def test_elastic_restore_with_sharding(tmp_path):
    """Restore onto explicit shardings (single-device 'mesh' here; the same
    code path re-shards onto any mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    got = ckpt.restore(d, 1, jax.tree.map(jnp.zeros_like, tree),
                       shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
