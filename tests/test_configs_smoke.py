"""Per-arch smoke tests: reduced config, one real step on CPU, shape + NaN
checks.  Exercises exactly the build_cell path the dry-run lowers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    SkippedCell, build_cell, materialize_cell, smoke_shapes,
)


def _run_cell(arch_id, shape_name):
    arch = get_arch(arch_id)
    mesh = make_host_mesh()
    cell = build_cell(arch, shape_name, mesh, smoke=True)
    args = materialize_cell(cell, seed=0)
    out = jax.jit(cell.step_fn)(*args)
    return cell, out


def _assert_finite(tree):
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), \
                "non-finite values in output"


LM_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "gnn"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train(arch_id):
    cell, (params, opt_state, metrics) = _run_cell(arch_id, "train_4k")
    assert metrics["loss"].shape == ()
    assert np.isfinite(float(metrics["loss"]))
    _assert_finite(params)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_decode(arch_id):
    cell, (logits, cache) = _run_cell(arch_id, "decode_32k")
    cfg = get_arch(arch_id).smoke
    assert logits.shape == (2, cfg.vocab)
    _assert_finite(logits)
    assert int(cache["len"]) >= 1


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_prefill(arch_id):
    cell, (logits, cache) = _run_cell(arch_id, "prefill_32k")
    cfg = get_arch(arch_id).smoke
    assert logits.shape == (2, cfg.vocab)
    _assert_finite(logits)


def test_gemma_long_context_smoke():
    cell, (logits, cache) = _run_cell("gemma3-1b", "long_500k")
    assert logits.shape[0] == 1
    _assert_finite(logits)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
@pytest.mark.parametrize("shape", ["full_graph_sm", "molecule"])
def test_gnn_smoke_train(arch_id, shape):
    cell, (params, opt_state, metrics) = _run_cell(arch_id, shape)
    assert np.isfinite(float(metrics["loss"]))
    _assert_finite(params)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke_large_shapes(arch_id):
    cell, (params, opt_state, metrics) = _run_cell(arch_id, "ogb_products")
    assert np.isfinite(float(metrics["loss"]))


def test_fm_smoke_all_shapes():
    for shape in ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"):
        cell, out = _run_cell("fm", shape)
        if shape == "train_batch":
            assert np.isfinite(float(out[2]["loss"]))
        else:
            _assert_finite(out)


def test_all_cells_enumerable():
    """40 cells: every (arch x shape) is either buildable or declared skip."""
    total, skipped = 0, 0
    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        for shape_name in arch.shapes:
            total += 1
            if arch.shapes[shape_name] is None:
                skipped += 1
                assert shape_name in arch.skip_notes, (
                    f"{arch_id}/{shape_name} skipped without a note")
    assert total == 40, total
    assert skipped == 4  # long_500k for 4 pure-full-attention LMs
