"""Loop-aware HLO cost model: validate against XLA cost_analysis and
analytic flop counts on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca  # list-of-dict on old jax


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    got = analyze_hlo(c.as_text())
    want = 2 * 128 * 256 * 512
    assert got["flops"] == pytest.approx(want, rel=0.05), got["flops"]
    # agrees with XLA on a loop-free program
    xla = _xla_cost(c)["flops"]
    assert got["flops"] == pytest.approx(xla, rel=0.05)


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def loop(w, x, n):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    c8 = _compile(lambda w, x: loop(w, x, 8), w, x)
    c16 = _compile(lambda w, x: loop(w, x, 16), w, x)
    f8 = analyze_hlo(c8.as_text())["flops"]
    f16 = analyze_hlo(c16.as_text())["flops"]
    assert f16 == pytest.approx(2 * f8, rel=0.05), (f8, f16)
    # and the absolute count is ~ n * matmul flops
    want = 8 * 2 * 8 * 64 * 64
    assert f8 == pytest.approx(want, rel=0.3), (f8, want)


def test_bytes_scale_with_loop():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def loop(x, n):
        def body(c, _):
            return c * 2.0 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    b4 = analyze_hlo(_compile(lambda x: loop(x, 4), x).as_text())["bytes"]
    b8 = analyze_hlo(_compile(lambda x: loop(x, 8), x).as_text())["bytes"]
    assert b8 > 1.5 * b4, (b4, b8)


def test_layers_scale_in_model_flops():
    """The regression this module exists for: flops must scale with layers."""
    import dataclasses
    from repro.models import transformer as tf

    base = tf.LMConfig(n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                       head_dim=32, d_ff=128, vocab=128, remat=True,
                       dtype="float32", attn_chunk=32)
    flops = {}
    for L in (2, 4):
        cfg = dataclasses.replace(base, n_layers=L)
        p = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.key(0)))
        b = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
        def grad(pp, bb, cfg=cfg):
            g = jax.grad(lambda q: tf.loss_fn(cfg, q, bb)[0])(pp)
            return jax.tree.map(lambda t: jnp.sum(t.astype(jnp.float32)), g)
        c = _compile(grad, p, b)
        flops[L] = analyze_hlo(c.as_text())["flops"]
        assert flops[L] != pytest.approx(_xla_cost(c)["flops"]) or L == 2
    ratio = flops[4] / flops[2]
    assert 1.3 < ratio < 2.2, flops
