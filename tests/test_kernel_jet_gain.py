"""jet_gain Pallas kernel vs pure-jnp oracle — shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as cn
from repro.data import graphs as gen
from repro.kernels.jet_gain.jet_gain import jet_gain_pallas
from repro.kernels.jet_gain.ops import csr_to_ell, jet_gain
from repro.kernels.jet_gain.ref import jet_gain_ref


def _rand_inputs(n, d, k, seed=0, wmax=8):
    rng = np.random.default_rng(seed)
    nbr_parts = rng.integers(0, k + 1, (n, d)).astype(np.int32)
    nwgt = rng.integers(0, wmax, (n, d)).astype(np.int32)
    nwgt[nbr_parts == k] = 0  # padding slots carry no weight
    parts = rng.integers(0, k, n).astype(np.int32)
    return jnp.asarray(nbr_parts), jnp.asarray(nwgt), jnp.asarray(parts)


@pytest.mark.parametrize("n,d,k,block", [
    (256, 8, 4, 64),
    (512, 16, 7, 128),
    (1024, 4, 13, 256),
    (128, 32, 31, 128),
    (2048, 5, 3, 512),
])
def test_kernel_matches_ref_sweep(n, d, k, block):
    nbr_parts, nwgt, parts = _rand_inputs(n, d, k, seed=n + d + k)
    want = jet_gain_ref(nbr_parts, nwgt, parts, k)
    got = jet_gain_pallas(nbr_parts, nwgt, parts, k, block_n=block)
    for w, g_ in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g_))


def test_kernel_tie_breaking_smallest_part():
    # two parts with equal connectivity -> smaller id must win, matching ref
    nbr_parts = jnp.asarray([[1, 2, 1, 2]], dtype=jnp.int32)
    nwgt = jnp.asarray([[3, 3, 2, 2]], dtype=jnp.int32)
    parts = jnp.asarray([0], dtype=jnp.int32)
    want = jet_gain_ref(nbr_parts, nwgt, parts, 4)
    got = jet_gain_pallas(
        jnp.tile(nbr_parts, (64, 1)), jnp.tile(nwgt, (64, 1)),
        jnp.tile(parts, 64), 4, block_n=64,
    )
    assert int(got[1][0]) == int(want[1][0]) == 1
    assert int(got[2][0]) == int(want[2][0]) == 5


def test_kernel_no_other_part():
    # vertex connected only to its own part -> best_part == k, best_conn == 0
    nbr_parts = jnp.zeros((64, 4), jnp.int32)
    nwgt = jnp.ones((64, 4), jnp.int32)
    parts = jnp.zeros((64,), jnp.int32)
    cs, bp, bc = jet_gain_pallas(nbr_parts, nwgt, parts, 3, block_n=64)
    assert int(cs[0]) == 4 and int(bp[0]) == 3 and int(bc[0]) == 0


@pytest.mark.parametrize("name", ["grid_64x32", "rmat_12"])
def test_ell_path_matches_csr_connectivity(name):
    """End-to-end: CSR->ELL + kernel == dense connectivity queries."""
    g = gen.suite_graph(name)
    k = 5
    rng = np.random.default_rng(3)
    parts = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    parts = jnp.where(g.vertex_mask(), parts, k)
    nbr, wgt = csr_to_ell(g)
    cs, bp, bc = jet_gain(nbr, wgt, parts, k, use_pallas=True)
    q = cn.dense_queries(g, parts, k)
    n = int(g.n)
    np.testing.assert_array_equal(np.asarray(cs)[:n], np.asarray(q.conn_self)[:n])
    np.testing.assert_array_equal(np.asarray(bc)[:n], np.asarray(q.best_conn)[:n])
    np.testing.assert_array_equal(np.asarray(bp)[:n], np.asarray(q.best_part)[:n])
