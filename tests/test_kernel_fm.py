"""fm_interaction kernel vs oracle + vs naive O(F^2) pairwise sum."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fm_interaction.ops import fm_interaction
from repro.kernels.fm_interaction.ref import fm_interaction_ref


def _naive(emb):
    b, f, d = emb.shape
    out = np.zeros(b)
    for i in range(f):
        for j in range(i + 1, f):
            out += np.sum(emb[:, i] * emb[:, j], axis=-1)
    return out


@pytest.mark.parametrize("b,f,d,block", [
    (64, 39, 10, 64),
    (128, 8, 16, 32),
    (100, 26, 32, 64),   # padding path
    (256, 4, 128, 256),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fm_kernel_sweep(b, f, d, block, dtype):
    rng = np.random.default_rng(b + f)
    emb = rng.standard_normal((b, f, d)).astype(np.float32)
    x = jnp.asarray(emb).astype(dtype)
    want = np.asarray(fm_interaction_ref(x), dtype=np.float32)
    got = np.asarray(fm_interaction(x, block_b=block), dtype=np.float32)
    rtol = 5e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


def test_fm_sum_square_trick_equals_naive():
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((16, 12, 8)).astype(np.float32)
    want = _naive(emb)
    got = np.asarray(fm_interaction(jnp.asarray(emb), block_b=16))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
