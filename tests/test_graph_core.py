"""Graph container, metrics, and connectivity backend tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as conn
from repro.core import metrics
from repro.core.graph import build_csr_host, graph_to_host, validate_host
from repro.data import graphs as gen


def test_build_csr_dedup_selfloop():
    # parallel edge (0,1)x2 -> weight 2; self loop dropped
    edges = np.array([[0, 1], [1, 0], [2, 2], [1, 2]])
    g = build_csr_host(3, edges)
    validate_host(g)
    assert int(g.n) == 3
    assert int(g.m) == 4  # 2 undirected edges x2 directions
    assert int(g.total_eweight()) == 3  # w(0,1)=2, w(1,2)=1


def test_build_csr_padding():
    edges = np.array([[0, 1], [1, 2]])
    g = build_csr_host(3, edges, n_max=8, m_max=16)
    validate_host(g)
    assert g.n_max == 8 and g.m_max == 16
    assert int(jnp.sum(g.vertex_mask())) == 3
    assert int(jnp.sum(g.edge_mask())) == 4
    assert int(g.total_vweight()) == 3


def test_roundtrip_host():
    g = gen.grid2d(5, 4)
    n, edges, ew, vw = graph_to_host(g)
    g2 = build_csr_host(n, edges, ew, vw)
    assert np.array_equal(np.asarray(g.xadj), np.asarray(g2.xadj))
    assert np.array_equal(np.asarray(g.adjncy), np.asarray(g2.adjncy))


def test_generators_valid():
    for name in gen.SUITE:
        g = gen.suite_graph(name)
        n, m = int(g.n), int(g.m)
        assert n > 0 and m > 0
        xadj = np.asarray(g.xadj)
        assert xadj[n] == m
        src = np.asarray(g.esrc)[:m]
        dst = np.asarray(g.adjncy)[:m]
        assert np.all(src != dst)


def test_cutsize_and_sizes():
    g = gen.grid2d(4, 4)  # 16 vertices
    k = 2
    parts = jnp.asarray((np.arange(16) % 16 >= 8).astype(np.int32))  # rows 0-1 | 2-3
    cut = int(metrics.cutsize(g, parts))
    assert cut == 4  # 4 vertical edges between row 1 and row 2
    sizes = metrics.part_sizes(g, parts, k)
    assert np.array_equal(np.asarray(sizes), [8, 8])
    assert float(metrics.imbalance(sizes, g.total_vweight(), k)) == pytest.approx(0.0)
    assert bool(metrics.is_balanced(sizes, g.total_vweight(), k, 0.03))


def test_boundary_mask():
    g = gen.grid2d(4, 4)
    parts = jnp.asarray((np.arange(16) >= 8).astype(np.int32))
    b = np.asarray(metrics.boundary_mask(g, parts))
    assert set(np.nonzero(b)[0]) == {4, 5, 6, 7, 8, 9, 10, 11}


def _brute_queries(g, parts, k):
    n, m = int(g.n), int(g.m)
    src = np.asarray(g.esrc)[:m]
    dst = np.asarray(g.adjncy)[:m]
    w = np.asarray(g.adjwgt)[:m]
    p = np.asarray(parts)
    mat = np.zeros((g.n_max, k + 1), dtype=np.int64)
    for e in range(m):
        mat[src[e], p[dst[e]]] += w[e]
    conn_self = mat[np.arange(g.n_max), p]
    best_part = np.full(g.n_max, k)
    best_conn = np.zeros(g.n_max, dtype=np.int64)
    for v in range(n):
        row = mat[v].copy()
        row[p[v]] = -1
        row[k] = -1
        bp = int(np.argmax(row))
        if row[bp] > 0:
            best_part[v], best_conn[v] = bp, row[bp]
    return conn_self, best_part, best_conn


@pytest.mark.parametrize("backend", ["dense", "sorted"])
@pytest.mark.parametrize("name", ["grid_64x32", "rmat_12", "smallworld_4k"])
def test_connectivity_backends_match_bruteforce(backend, name):
    g = gen.suite_graph(name)
    k = 7  # odd k to catch modular bugs
    rng = np.random.default_rng(1)
    parts = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    q = conn.queries(g, parts, k, backend=backend)
    cs, bp, bc = _brute_queries(g, parts, k)
    nm = g.n_max
    assert np.array_equal(np.asarray(q.conn_self)[:nm], cs)
    assert np.array_equal(np.asarray(q.best_conn)[:nm], bc)
    assert np.array_equal(np.asarray(q.best_part)[:nm], bp)


def test_backends_agree_padded():
    g = gen.grid2d(8, 8)
    n, edges, ew, vw = graph_to_host(g)
    gp = build_csr_host(n, edges, ew, vw, n_max=100, m_max=300)
    k = 4
    rng = np.random.default_rng(2)
    parts = np.full(100, k, dtype=np.int32)
    parts[:n] = rng.integers(0, k, n)
    parts = jnp.asarray(parts)
    qd = conn.queries(gp, parts, k, backend="dense")
    qs = conn.queries(gp, parts, k, backend="sorted")
    for a, b in zip(qd, qs):
        assert np.array_equal(np.asarray(a)[:n], np.asarray(b)[:n])
