"""Fleet partitioning (DESIGN.md §10): vmapped V-cycles over shape buckets.

The load-bearing property mirrors §9's: batching whole graphs changes the
SCHEDULE, never the VALUES — every fleet member's parts vector, cut, and
per-trial stats are bit-identical to its standalone ``partition()`` run,
on every backend, under mixed bucket occupancy (graphs of different true
sizes sharing one capacity bucket) and per-graph coarsening depths.
Plus: the bucketing policy itself, stack/unstack round-trips, the CLI's
nonzero exit on unbalanced selections, and the CI quality gate's
regression detection.
"""
import json

import numpy as np
import pytest

from repro.core import graph as gr
from repro.core.partition import PartitionConfig, partition, partition_fleet
from repro.data import graphs as gen

# grid 13x13 and 12x12 round to one capacity rung (mixed occupancy);
# grid 8x8 lands in its own smaller bucket
FLEET = ((13, 13), (12, 12), (8, 8))


def _fleet_graphs():
    return [gen.grid2d(a, b) for a, b in FLEET]


def _cfg(backend, k, **kw):
    return PartitionConfig(k=k, backend=backend, coarse_target=48,
                           max_iter=30, patience=3, **kw)


@pytest.mark.parametrize("backend", ["dense", "sorted", "ell"])
@pytest.mark.parametrize("k", [2, 8, 33])
def test_fleet_bit_identical_to_standalone(backend, k):
    """Fleet member i == standalone partition(graphs[i]): parts, cut,
    balance, level count — for every k, on every backend."""
    graphs = _fleet_graphs()
    cfg = _cfg(backend, k)
    fres = partition_fleet(graphs, cfg)
    assert len(fres.results) == len(graphs)
    # mixed occupancy must actually happen: the two big grids share a bucket
    sizes = {len(b.indices) for b in fres.buckets}
    assert 2 in sizes, [b.indices for b in fres.buckets]
    for i, g in enumerate(graphs):
        solo = partition(g, cfg)
        fleet = fres.results[i]
        assert fleet.cut == solo.cut, (backend, k, i)
        assert fleet.balanced == solo.balanced
        assert fleet.levels == solo.levels
        assert fleet.parts.shape == solo.parts.shape
        np.testing.assert_array_equal(
            np.asarray(fleet.parts), np.asarray(solo.parts)
        )


def test_fleet_composes_with_trials():
    """B graphs × T trials in one program: per-trial cuts and the selected
    best match the standalone trials run, per member."""
    graphs = _fleet_graphs()
    cfg = _cfg("dense", 8, trials=2)
    fres = partition_fleet(graphs, cfg)
    for i, g in enumerate(graphs):
        solo = partition(g, cfg)
        fleet = fres.results[i]
        assert fleet.trial_cuts == solo.trial_cuts, i
        assert fleet.trial_balanced == solo.trial_balanced
        assert fleet.best_trial == solo.best_trial
        assert fleet.cut == solo.cut
        np.testing.assert_array_equal(
            np.asarray(fleet.parts), np.asarray(solo.parts)
        )
        # trial_parts honor the standalone contract: same shape as the
        # caller's padding, rows bit-equal to the solo batch
        assert fleet.trial_parts.shape == solo.trial_parts.shape
        np.testing.assert_array_equal(
            np.asarray(fleet.trial_parts), np.asarray(solo.trial_parts)
        )


def test_bucket_graphs_policy():
    """Near-sized graphs share a rung pair; distinct sizes split; every
    graph fits its assigned capacity."""
    graphs = _fleet_graphs()
    schedule, buckets = gr.bucket_graphs(graphs)
    assert sum(len(v) for v in buckets.values()) == len(graphs)
    assigned = {i: cap for cap, idxs in buckets.items() for i in idxs}
    assert assigned[0] == assigned[1] != assigned[2]
    for i, g in enumerate(graphs):
        n_cap, m_cap = assigned[i]
        assert int(g.n) <= n_cap and int(g.m) <= m_cap
        assert (n_cap, m_cap) in [
            (nc, mc)
            for nc, _ in schedule for _, mc in schedule
        ]


def test_stack_unstack_roundtrip():
    g1 = gen.grid2d(6, 6)
    g2 = gen.grid2d(5, 5).with_capacity(g1.n_max, g1.m_max)
    gb = gr.stack_graphs([g1, g2])
    assert gb.vwgt.shape == (2, g1.n_max)
    assert gb.xadj.shape == (2, g1.n_max + 1)
    for b, g in enumerate((g1, g2)):
        back = gr.unstack_graph(gb, b)
        for leaf, orig in zip(back, g):
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(orig))
    with pytest.raises(ValueError):
        gr.stack_graphs([g1, gen.grid2d(5, 5)])


def test_fleet_overpadded_member():
    """A member padded far beyond its bucket capacity gets its results
    padded back to its own n_max (parts and trial_parts alike)."""
    from repro.core.graph import build_csr_host, graph_to_host

    g_small = gen.grid2d(8, 8)
    n, edges, ew, vw = graph_to_host(g_small)
    g_over = build_csr_host(n, edges, ew, vw, n_max=1024, m_max=1024)
    graphs = [gen.grid2d(13, 13), g_over]
    cfg = _cfg("dense", 4, trials=2)
    fres = partition_fleet(graphs, cfg)
    res = fres.results[1]
    assert res.parts.shape == (1024,)
    assert res.trial_parts.shape == (2, 1024)
    solo = partition(g_over, cfg)
    assert res.cut == solo.cut
    np.testing.assert_array_equal(np.asarray(res.parts),
                                  np.asarray(solo.parts))
    assert (np.asarray(res.parts)[n:] == 4).all()  # ghost part beyond n


def test_fleet_rejects_empty():
    with pytest.raises(ValueError):
        partition_fleet([], _cfg("dense", 4))


def test_cli_exits_nonzero_on_unbalanced(monkeypatch, capsys):
    """The CLI must fail loudly (nonzero + stderr reason) when the selected
    partition misses the balance constraint, so CI/fleet callers can gate
    on the return code."""
    from dataclasses import replace

    from repro.launch import partition_cli as cli

    real_partition = cli.partition

    def unbalanced_partition(g, cfg):
        res = real_partition(g, cfg)
        return replace(res, balanced=False, imbalance=0.5)

    monkeypatch.setattr(cli, "partition", unbalanced_partition)
    rc = cli.main(["--graph", "grid", "--size", "8", "--k", "2",
                   "--coarse-target", "16"])
    assert rc == 1
    assert "unbalanced" in capsys.readouterr().err
    # the escape hatch keeps the old always-zero behaviour available
    monkeypatch.setattr(cli, "partition", real_partition)
    rc = cli.main(["--graph", "grid", "--size", "8", "--k", "2",
                   "--coarse-target", "16"])
    assert rc == 0


def test_cli_fleet_mode(capsys):
    from repro.launch import partition_cli as cli

    rc = cli.main(["--fleet", "grid:8", "grid:7", "--k", "2",
                   "--coarse-target", "16", "--allow-unbalanced"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert len(report["fleet"]) == 2
    assert {m for b in report["buckets"] for m in b["members"]} == {0, 1}
    for entry in report["fleet"]:
        assert entry["cut"] > 0


def test_check_baseline_gate():
    """The CI quality gate passes on identical numbers and fails on an
    injected cut/balance regression."""
    from benchmarks.bench_partitioner import compare_baseline

    base = {
        "baseline_tolerance": 0.05,
        "coarsen_mode_ab": {"smoke": {"device": {"cut": 36},
                                      "host": {"cut": 36}}},
        "trials_ab": {"smoke": {"best_cut": 36, "trial_cuts": [36, 43]}},
        "fleet_ab": {"smoke": {"cuts": {"g16": 36}, "balanced": {"g16": True}}},
    }
    fresh = json.loads(json.dumps(base))
    assert compare_baseline(fresh, base) == []
    # within tolerance: still passes
    fresh["trials_ab"]["smoke"]["best_cut"] = 37
    assert compare_baseline(fresh, base) == []
    # injected cut regression: fails
    fresh["trials_ab"]["smoke"]["best_cut"] = 45
    bad = compare_baseline(fresh, base)
    assert bad and "trials_ab/smoke/best_cut" in bad[0]
    # injected balance regression: fails
    fresh["trials_ab"]["smoke"]["best_cut"] = 36
    fresh["fleet_ab"]["smoke"]["balanced"]["g16"] = False
    bad = compare_baseline(fresh, base)
    assert bad and "balanced" in bad[0]
    # a dropped/renamed smoke metric is itself a gate failure
    fresh = json.loads(json.dumps(base))
    del fresh["fleet_ab"]["smoke"]["cuts"]["g16"]
    bad = compare_baseline(fresh, base)
    assert bad and "missing from the fresh run" in bad[0]
    # incomparable reports never pass vacuously
    assert compare_baseline({}, base)
