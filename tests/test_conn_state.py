"""Stateful incremental refinement: ConnState.apply_moves must agree
bit-exactly with a from-scratch rebuild — connectivity structure, part
sizes, and cutsize — across many random move lists (paper Alg 4.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as cn
from repro.core import metrics, refine
from repro.core.graph import build_csr_host
from repro.data import graphs as gen


def _weighted_graph(seed=0, n=200, n_edges=700):
    rng = np.random.default_rng(seed)
    path = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    extra = rng.integers(0, n, (n_edges, 2))
    edges = np.concatenate([path, extra])
    edges = edges[edges[:, 0] != edges[:, 1]]
    ew = rng.integers(1, 8, edges.shape[0])
    vw = rng.integers(1, 4, n)
    return build_csr_host(n, edges, ew, vw)


def _rand_parts(g, k, rng):
    p = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    return jnp.where(g.vertex_mask(), p, k)


def _rand_moves(g, parts, k, rng, frac=0.15):
    move = jnp.asarray(rng.random(g.n_max) < frac) & g.vertex_mask()
    dest = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    return move, jnp.where(move, dest, parts)


def _assert_states_equal(st, ref, backend):
    np.testing.assert_array_equal(np.asarray(st.sizes), np.asarray(ref.sizes))
    assert int(st.cut) == int(ref.cut)
    if backend == "dense":
        np.testing.assert_array_equal(np.asarray(st.mat), np.asarray(ref.mat))
    elif backend == "sorted":
        np.testing.assert_array_equal(
            np.asarray(st.edge_dst_part), np.asarray(ref.edge_dst_part)
        )
    elif backend == "ell":
        np.testing.assert_array_equal(
            np.asarray(st.ell_parts), np.asarray(ref.ell_parts)
        )


@pytest.mark.parametrize("backend", ["dense", "sorted"])
@pytest.mark.parametrize("k", [2, 8, 33])
def test_apply_moves_matches_rebuild(backend, k):
    """10+ random move lists: incremental state == rebuilt state, bit-exact."""
    g = _weighted_graph(seed=k)
    rng = np.random.default_rng(100 + k)
    parts = _rand_parts(g, k, rng)
    st = cn.build_state(g, parts, k, backend)
    for step in range(12):
        move, dest = _rand_moves(g, parts, k, rng)
        st = cn.apply_moves(g, st, parts, move, dest, k, backend)
        parts = jnp.where(move, dest, parts)
        ref = cn.build_state(g, parts, k, backend)
        _assert_states_equal(st, ref, backend)
        # the maintained state answers queries identically to a rebuild
        qa = cn.state_queries(g, st, parts, k, backend)
        qb = cn.queries(g, parts, k, backend=backend)
        for a, b in zip(qa, qb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(st.moves_applied) == 12


@pytest.mark.parametrize("k", [2, 7])
def test_apply_moves_matches_rebuild_ell(k):
    """The Pallas ELL backend participates in the stateful interface."""
    g = gen.grid2d(12, 12)
    md = int(np.max(np.asarray(g.degrees())))
    rng = np.random.default_rng(3)
    parts = _rand_parts(g, k, rng)
    st = cn.build_state(g, parts, k, "ell", max_degree=md)
    for step in range(4):
        move, dest = _rand_moves(g, parts, k, rng)
        st = cn.apply_moves(g, st, parts, move, dest, k, "ell")
        parts = jnp.where(move, dest, parts)
        ref = cn.build_state(g, parts, k, "ell", max_degree=md)
        _assert_states_equal(st, ref, "ell")
        n = int(g.n)
        qa = cn.state_queries(g, st, parts, k, "ell")
        qb = cn.queries(g, parts, k, backend="dense")
        for a, b in zip(qa, qb):
            np.testing.assert_array_equal(np.asarray(a)[:n], np.asarray(b)[:n])


def test_delta_metrics_match_recompute():
    g = _weighted_graph(seed=9)
    k = 6
    rng = np.random.default_rng(9)
    parts = _rand_parts(g, k, rng)
    sizes = metrics.part_sizes(g, parts, k)
    cut = metrics.cutsize(g, parts)
    for _ in range(10):
        move, dest = _rand_moves(g, parts, k, rng, frac=0.3)
        parts2 = jnp.where(move, dest, parts)
        sizes = metrics.delta_part_sizes(g, sizes, parts, move, dest, k)
        cut = metrics.delta_cutsize(g, cut, parts, parts2)
        parts = parts2
        np.testing.assert_array_equal(
            np.asarray(sizes), np.asarray(metrics.part_sizes(g, parts, k))
        )
        assert int(cut) == int(metrics.cutsize(g, parts))


@pytest.mark.parametrize("backend", ["dense", "sorted"])
def test_refine_incremental_equals_rebuild_every(backend):
    """rebuild_every=1 (legacy full rebuild per iteration) and the default
    incremental path must walk identical trajectories."""
    g = gen.grid2d(20, 20)
    k = 5
    rng = np.random.default_rng(11)
    parts0 = _rand_parts(g, k, rng)
    p_inc, s_inc = refine.jet_refine(g, parts0, k, lam=0.05, backend=backend,
                                     max_iter=60, rebuild_every=0)
    p_rbd, s_rbd = refine.jet_refine(g, parts0, k, lam=0.05, backend=backend,
                                     max_iter=60, rebuild_every=1)
    np.testing.assert_array_equal(np.asarray(p_inc), np.asarray(p_rbd))
    assert int(s_inc["iterations"]) == int(s_rbd["iterations"])
    assert int(s_inc["best_cost"]) == int(s_rbd["best_cost"])
    # periodic hatch lands on the same answer too
    p_per, s_per = refine.jet_refine(g, parts0, k, lam=0.05, backend=backend,
                                     max_iter=60, rebuild_every=7)
    np.testing.assert_array_equal(np.asarray(p_inc), np.asarray(p_per))
