"""Coarsening: matching validity, contraction invariants, multilevel hierarchy."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coarsen
from repro.core.graph import build_csr_host, validate_host
from repro.data import graphs as gen


def _check_matching(g, match):
    n = int(g.n)
    m = np.asarray(match)
    for v in range(n):
        if m[v] >= 0:
            assert m[v] != v or True  # self allowed only for pads
            assert m[m[v]] == v, f"not involution at {v}"


@pytest.mark.parametrize("name", ["grid_64x32", "rmat_12", "cube_12"])
def test_hem_valid_involution(name):
    g = gen.suite_graph(name)
    match = coarsen.heavy_edge_matching(g)
    _check_matching(g, match)
    frac = float(np.mean(np.asarray(match)[: int(g.n)] >= 0))
    # Meshes match well with pure HEM; power-law graphs do not (which is
    # exactly why the paper adds two-hop matching at >25% unmatched).
    assert frac > (0.25 if name == "rmat_12" else 0.5), f"HEM matched {frac:.0%}"
    if frac < 0.75:
        match2 = coarsen.twohop_matching(g, match)
        _check_matching(g, match2)
        frac2 = float(np.mean(np.asarray(match2)[: int(g.n)] >= 0))
        assert frac2 > frac + 0.1, f"two-hop didn't help: {frac:.0%}->{frac2:.0%}"


def test_twohop_star():
    # star graph: HEM matches center with one leaf; remaining leaves
    # are two-hop "leaves" and should pair up.
    g = gen.star(10)
    match = coarsen.heavy_edge_matching(g)
    match = coarsen.twohop_matching(g, match)
    _check_matching(g, match)
    matched = np.asarray(match)[:10] >= 0
    assert matched.sum() >= 8  # at most one leftover leaf + maybe none


def test_contraction_preserves_weight():
    g = gen.suite_graph("rmat_12")
    gc, cmap = coarsen.coarsen_once(g)
    validate_host(gc)
    # vertex weight conserved
    assert int(gc.total_vweight()) == int(g.total_vweight())
    # edge weight: coarse total + internal = fine total
    cu = np.asarray(cmap)[np.asarray(g.esrc)[: int(g.m)]]
    cv = np.asarray(cmap)[np.asarray(g.adjncy)[: int(g.m)]]
    w = np.asarray(g.adjwgt)[: int(g.m)]
    internal = w[cu == cv].sum() // 2
    assert int(gc.total_eweight()) + internal == int(g.total_eweight())


def test_contraction_no_self_loops_no_dups():
    g = gen.suite_graph("smallworld_4k")
    gc, cmap = coarsen.coarsen_once(g)
    m = int(gc.m)
    src = np.asarray(gc.esrc)[:m]
    dst = np.asarray(gc.adjncy)[:m]
    assert np.all(src != dst)
    keys = src.astype(np.int64) * int(gc.n) + dst
    assert np.unique(keys).shape[0] == m


def test_multilevel_hierarchy():
    g = gen.suite_graph("rmat_12")
    levels = coarsen.multilevel_coarsen(g, coarse_target=256)
    assert len(levels) >= 2
    sizes = [int(lv.graph.n) for lv in levels]
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] <= max(256, int(0.95 * sizes[-2]) + 1)
    # every level conserves vertex weight
    for lv in levels:
        assert int(lv.graph.total_vweight()) == int(g.total_vweight())
    # cmaps project: fine vertex -> valid coarse vertex
    for i, lv in enumerate(levels[:-1]):
        nc = int(levels[i + 1].graph.n)
        cm = np.asarray(lv.cmap)[: int(lv.graph.n)]
        assert cm.min() >= 0 and cm.max() < nc
        # surjective: every coarse vertex has a fine preimage
        assert np.unique(cm).shape[0] == nc


def test_project_partition():
    g = gen.grid2d(8, 8)
    gc, cmap = coarsen.coarsen_once(g)
    nc = int(gc.n)
    rng = np.random.default_rng(0)
    pc = jnp.asarray(rng.integers(0, 4, gc.n_max).astype(np.int32))
    pf = coarsen.project_partition(cmap, pc)
    pf = np.asarray(pf)
    cm = np.asarray(cmap)
    for v in range(int(g.n)):
        assert pf[v] == np.asarray(pc)[cm[v]]
