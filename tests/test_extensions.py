"""Extended coverage: ELL/Pallas connectivity backend, incremental Alg 4.4
update, prefill->decode continuation consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as cn
from repro.data import graphs as gen
from repro.models import transformer as tf


@pytest.mark.parametrize("name", ["grid_64x32", "rmat_12"])
def test_ell_backend_matches_dense(name):
    """The jet_gain Pallas kernel as a first-class connectivity backend."""
    g = gen.suite_graph(name)
    k = 6
    rng = np.random.default_rng(4)
    parts = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    parts = jnp.where(g.vertex_mask(), parts, k)
    qd = cn.queries(g, parts, k, backend="dense")
    qe = cn.queries(g, parts, k, backend="ell")
    n = int(g.n)
    for a, b in zip(qd, qe):
        np.testing.assert_array_equal(np.asarray(a)[:n], np.asarray(b)[:n])


def test_incremental_update_matches_rebuild():
    """Paper Alg 4.4: incremental connectivity update == full rebuild."""
    g = gen.suite_graph("smallworld_4k")
    k = 5
    rng = np.random.default_rng(7)
    parts = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    parts = jnp.where(g.vertex_mask(), parts, k)
    mat = cn.conn_matrix(g, parts, k)
    # random move list: ~20% of vertices change part
    move = jnp.asarray((rng.random(g.n_max) < 0.2)) & g.vertex_mask()
    dest = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    dest = jnp.where(move, dest, parts)
    mat2 = cn.update_conn_matrix(mat, g, parts, move, dest)
    parts_new = jnp.where(move, dest, parts)
    want = cn.conn_matrix(g, parts_new, k)
    np.testing.assert_array_equal(np.asarray(mat2), np.asarray(want))


@pytest.mark.parametrize("kind", ["gqa", "mla"])
def test_prefill_then_decode_matches_full_forward(kind):
    """Serve path integration: prefill a prompt, decode continuations, and
    check every decode logit against the monolithic forward pass."""
    if kind == "mla":
        cfg = tf.LMConfig(
            n_layers=2, d_model=32, n_heads=2, attn_kind="mla",
            kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
            vocab=53, attn_chunk=4, remat=False, dtype="float32")
    else:
        cfg = tf.LMConfig(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab=53, attn_chunk=4, remat=False, dtype="float32")
    p = tf.init_params(cfg, jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (2, 12), 0, 53)
    full, _ = tf.forward(cfg, p, toks)

    prompt_len = 8
    logits, cache = tf.prefill(cfg, p, toks[:, :prompt_len], max_len=12)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, prompt_len - 1]),
        rtol=2e-4, atol=2e-4)
    for i in range(prompt_len, 12):
        logits, cache = tf.decode_step(cfg, p, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, i]), rtol=2e-4, atol=2e-4)


def test_grad_cast_and_seq_parallel_flags_preserve_loss():
    """The §Perf tuning flags must not change the forward loss."""
    import dataclasses

    cfg = tf.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      head_dim=16, d_ff=64, vocab=53, remat=True,
                      dtype="float32", attn_chunk=16)
    p = tf.init_params(cfg, jax.random.key(0))
    b = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, 53),
         "labels": jax.random.randint(jax.random.key(2), (2, 16), 0, 53)}
    base = float(tf.loss_fn(cfg, p, b)[0])
    for flags in ({"seq_parallel": True}, {"grad_cast": True},
                  {"seq_parallel": True, "grad_cast": True}):
        cfg2 = dataclasses.replace(cfg, **flags)
        assert float(tf.loss_fn(cfg2, p, b)[0]) == pytest.approx(base)
