"""§11 serving: coalesced responses must be standalone-bit-identical.

The server's contract mirrors §9/§10: micro-batching changes the
SCHEDULE (who shares a dispatch, a bucket, a lane), never the VALUES —
every response equals the ``partition()`` result for the same config.
Plus: warmup covers the replay (zero post-warmup compiles), admission
rejects oversized graphs with the queue intact, and the CLI rejects
duplicate fleet member names.
"""
import asyncio
from dataclasses import replace

import numpy as np
import pytest

from repro.core.partition import PartitionConfig, partition
from repro.data import graphs as gen

pytest.importorskip("repro.launch.partition_serve")

from repro.launch.partition_serve import (  # noqa: E402
    PartitionServer, ServeConfig, serve_signatures,
)

# grid 6x6 and 6x5 round to one (64, 128) rung on the (64, 256) ladder
# (mixed-occupancy bucket); 4x4 lands in its own (64, 64) bucket
BASE = PartitionConfig(k=2, coarse_target=32, max_iter=30, patience=3)


def _server(**kw):
    return PartitionServer(ServeConfig(
        ladder_n=64, ladder_m=256, window_s=0.02, lanes=2,
        partition=BASE, **kw,
    ))


def test_serve_bit_identical_mixed_shape_mixed_k():
    server = _server()
    gs = [gen.grid2d(6, 6), gen.grid2d(6, 5), gen.grid2d(4, 4)]
    ks = [2, 2, 3]

    async def run():
        async with server:
            return await asyncio.gather(
                *(server.submit(g, k=k) for g, k in zip(gs, ks)))

    results = asyncio.run(run())
    for g, k, res in zip(gs, ks, results):
        solo = partition(g, replace(BASE, k=k))
        assert res.cut == solo.cut, k
        assert res.balanced == solo.balanced
        assert res.trial_cuts == solo.trial_cuts
        assert res.parts.shape == solo.parts.shape
        np.testing.assert_array_equal(np.asarray(res.parts),
                                      np.asarray(solo.parts))
    # the burst coalesced: the two near-sized grids shared one bucket
    occ = server.stats["occupancy_hist"]
    assert occ.get(2, 0) >= 1, occ
    # every dispatched bucket was pinned to the configured lane width
    assert server.dispatch_log
    for d in server.dispatch_log:
        assert all(b["lanes"] == 2 for b in d["buckets"])


def test_warmup_covers_replay():
    """After the AOT pass over the workload's shapes × k grid, replaying
    compiles zero new fleet executables."""
    from repro.core.partition import uncoarsen_level_fleet

    server = _server()
    shapes = [gen.grid2d(6, 6), gen.grid2d(6, 5), gen.grid2d(4, 4)]
    rep = server.warmup(shapes, ks=(2, 3))
    assert rep["new_executables"] >= 0
    assert len(serve_signatures(server.warmup_log)) > 0

    execs0 = uncoarsen_level_fleet._cache_size()

    async def run():
        async with server:
            return await asyncio.gather(
                server.submit(shapes[0], k=2),
                server.submit(shapes[1], k=2),
                server.submit(shapes[2], k=3),
            )

    results = asyncio.run(run())
    assert all(r.cut >= 0 for r in results)
    assert uncoarsen_level_fleet._cache_size() == execs0, \
        "replay after warmup must not compile new executables"
    assert serve_signatures(server.dispatch_log) <= \
        serve_signatures(server.warmup_log)


def test_oversized_request_rejected_queue_intact():
    server = _server()
    big = gen.grid2d(30, 30)  # n=900 over the 64-vertex ladder top

    async def run():
        async with server:
            with pytest.raises(ValueError, match="ladder"):
                await server.submit(big, k=2)
            # the server keeps serving after a rejection
            return await server.submit(gen.grid2d(4, 4), k=2)

    res = asyncio.run(run())
    solo = partition(gen.grid2d(4, 4), replace(BASE, k=2))
    assert res.cut == solo.cut
    assert server.stats["rejected"] == 1


def test_submit_requires_started_server():
    server = _server()

    async def run():
        with pytest.raises(RuntimeError, match="not started"):
            await server.submit(gen.grid2d(4, 4), k=2)

    asyncio.run(run())


def test_cli_fleet_rejects_duplicate_member_names(capsys):
    from repro.launch.partition_cli import main

    rc = main(["--fleet", "grid:8", "grid:8", "--k", "2"])
    captured = capsys.readouterr()
    assert rc == 2
    assert "duplicate" in captured.err
    assert "grid:8" in captured.err
    # distinct seeds make distinct members — accepted (parse-level check:
    # the specs differ, so no early exit on the duplicate path)
    from repro.launch.partition_cli import _parse_fleet_spec

    assert _parse_fleet_spec("grid:8:0", 16, 0) != \
        _parse_fleet_spec("grid:8:1", 16, 0)
