"""Bucketing/stacking invariants (DESIGN.md §10-§11), property-style.

The fleet and serving paths both stand on three graph-layer contracts:
``bucket_graphs`` assigns every member a rung it actually fits (the
smallest fitting one, per axis), ``stack_graphs``/``unstack_graph``
round-trip bit-identically, and a single-member bucket is literally its
member (re-padded).  Swept over seeded random shapes like
tests/test_matching_properties.py sweeps matchings.
"""
import numpy as np
import pytest

from repro.core import graph as gr
from repro.core.coarsen import select_capacity, shape_schedule
from repro.data import graphs as gen

SEEDS = [0, 1, 7]


def _random_fleet(seed: int, count: int = 6):
    """A seeded mixed-family fleet with clustered sizes (so some members
    share rungs) and outliers (so some don't)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(count):
        fam = rng.integers(0, 3)
        if fam == 0:
            r = int(rng.integers(5, 14))
            out.append(gen.grid2d(r, max(2, r - int(rng.integers(0, 2)))))
        elif fam == 1:
            out.append(gen.small_world(int(rng.integers(32, 160)),
                                       seed=int(rng.integers(1 << 16))))
        else:
            out.append(gen.random_geometric(int(rng.integers(32, 128)),
                                            seed=int(rng.integers(1 << 16))))
    return out


@pytest.mark.parametrize("seed", SEEDS)
def test_every_member_fits_its_rung(seed):
    graphs = _random_fleet(seed)
    schedule, buckets = gr.bucket_graphs(graphs)
    assigned = {i: cap for cap, idxs in buckets.items() for i in idxs}
    assert sorted(assigned) == list(range(len(graphs)))
    n_rungs = sorted({nc for nc, _ in schedule})
    m_rungs = sorted({mc for _, mc in schedule})
    for i, g in enumerate(graphs):
        n, m = int(g.n), int(g.m)
        n_cap, m_cap = assigned[i]
        # fits ...
        assert n <= n_cap and m <= m_cap, (i, (n, m), (n_cap, m_cap))
        # ... and is the SMALLEST fitting rung per axis
        assert n_cap == min(r for r in n_rungs if r >= n)
        assert m_cap == min(r for r in m_rungs if r >= m)
        assert (n_cap, m_cap) == select_capacity(schedule, n, m)


@pytest.mark.parametrize("seed", SEEDS)
def test_fixed_schedule_assignment_is_stable(seed):
    """On a pinned ladder (the §11 serving contract), each graph's rung
    depends only on its own (n, m) — never on the rest of the fleet."""
    graphs = _random_fleet(seed)
    schedule = shape_schedule(512, 4096, align=64)
    _, together = gr.bucket_graphs(graphs, schedule=schedule)
    assigned = {i: cap for cap, idxs in together.items() for i in idxs}
    for i, g in enumerate(graphs):
        _, alone = gr.bucket_graphs([g], schedule=schedule)
        assert list(alone) == [assigned[i]], i
    # an oversized graph is rejected instead of silently re-laddering
    with pytest.raises(ValueError, match="top rung"):
        gr.bucket_graphs([gen.grid2d(30, 30)],
                         schedule=shape_schedule(64, 256, align=64))


@pytest.mark.parametrize("seed", SEEDS)
def test_stack_unstack_roundtrip_bit_identical(seed):
    graphs = _random_fleet(seed, count=4)
    schedule, buckets = gr.bucket_graphs(graphs)
    for cap, idxs in buckets.items():
        members = [graphs[i].with_capacity(*cap) for i in idxs]
        gb = gr.stack_graphs(members)
        for b, mem in enumerate(members):
            back = gr.unstack_graph(gb, b)
            for name, leaf, orig in zip(gr.Graph._fields, back, mem):
                np.testing.assert_array_equal(
                    np.asarray(leaf), np.asarray(orig),
                    err_msg=f"{cap}/{b}/{name}")


@pytest.mark.parametrize("seed", SEEDS)
def test_single_member_bucket_is_its_member(seed):
    """A bucket of one: stacking then unstacking lane 0 returns the
    member (at bucket capacity) bit-identically — padding never leaks
    into values."""
    g = _random_fleet(seed, count=1)[0]
    schedule, buckets = gr.bucket_graphs([g])
    (cap, idxs), = buckets.items()
    assert idxs == [0]
    padded = g.with_capacity(*cap)
    gb = gr.stack_graphs([padded])
    back = gr.unstack_graph(gb, 0)
    for name, leaf, orig in zip(gr.Graph._fields, back, padded):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(orig),
                                      err_msg=name)
    # and the true payload is untouched by the re-padding
    n, m = int(g.n), int(g.m)
    assert int(back.n) == n and int(back.m) == m
    np.testing.assert_array_equal(np.asarray(back.vwgt)[:n],
                                  np.asarray(g.vwgt)[:n])
    np.testing.assert_array_equal(np.asarray(back.adjncy)[:m],
                                  np.asarray(g.adjncy)[:m])


@pytest.mark.parametrize("seed", SEEDS)
def test_bucket_assembler_matches_bucket_graphs(seed):
    """Incremental assembly (add/flush) lands every graph in the same
    rung as the one-shot path, preserves tags, and its stacked lanes are
    bit-identical to the members."""
    graphs = _random_fleet(seed)
    schedule = shape_schedule(512, 4096, align=64)
    _, expect = gr.bucket_graphs(graphs, schedule=schedule)
    asm = gr.BucketAssembler(schedule)
    for i, g in enumerate(graphs):
        asm.add(i, g)
    assert len(asm) == len(graphs)
    flushed = asm.flush()
    assert len(asm) == 0 and asm.flush() == []
    got = {sb.capacity: list(sb.tags) for sb in flushed}
    assert got == expect
    for sb in flushed:
        assert sb.graph.vwgt.shape == (len(sb.tags), sb.capacity[0])
        for b, tag in enumerate(sb.tags):
            member = graphs[tag].with_capacity(*sb.capacity)
            back = gr.unstack_graph(sb.graph, b)
            for name, leaf, orig in zip(gr.Graph._fields, back, member):
                np.testing.assert_array_equal(
                    np.asarray(leaf), np.asarray(orig), err_msg=name)
            assert sb.orig_n_max[b] == graphs[tag].n_max


def test_bucket_assembler_fixed_lanes():
    """lanes=N pins every flushed bucket to width N: short buckets pad
    with filler copies of lane 0 (tag None), long buckets split."""
    schedule = shape_schedule(256, 2048, align=64)
    gs = [gen.grid2d(6, 6), gen.grid2d(6, 5), gen.grid2d(6, 4)]
    asm = gr.BucketAssembler(schedule, lanes=2)
    for i, g in enumerate(gs):
        asm.add(f"req{i}", g)
    flushed = asm.flush()
    for sb in flushed:
        assert len(sb.tags) == 2
        assert sb.graph.vwgt.shape[0] == 2
    tags = sorted(t for sb in flushed for t in sb.tags if t is not None)
    assert tags == ["req0", "req1", "req2"]
    fillers = [sb for sb in flushed if None in sb.tags]
    assert fillers, "3 members at width 2 must leave one filler lane"
    for sb in fillers:
        j = sb.tags.index(None)
        # filler lane is a bit-copy of lane 0 (same capacity, valid graph)
        for leaf in sb.graph:
            np.testing.assert_array_equal(np.asarray(leaf[j]),
                                          np.asarray(leaf[0]))
    with pytest.raises(ValueError):
        gr.BucketAssembler(schedule, lanes=0)
