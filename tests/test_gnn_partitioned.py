"""Partition-aware GNN distribution: numerical equivalence with the dense
reference under a real multi-device shard_map (8 host devices, subprocess
so the 512-device dry-run env stays isolated)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core.partition import PartitionConfig, partition
from repro.core.graph import build_csr_host, graph_to_host
from repro.data import graphs as gen
from repro.launch.gnn_partitioned import (
    build_partitioned_batch, partitioned_gnn_cell)
from repro.configs import get_arch
from repro.models.gnn import meshgraphnet
from repro.models.gnn.common import GraphBatch

K = 8
g = gen.grid2d(16, 16)  # 256 nodes
n = int(g.n)
rng = np.random.default_rng(0)
feats = rng.standard_normal((n, 4)).astype(np.float32)
pos = rng.standard_normal((n, 3)).astype(np.float32)
target = rng.standard_normal((n, 2)).astype(np.float32)
m = int(g.m)
edges = np.stack([np.asarray(g.esrc)[:m], np.asarray(g.adjncy)[:m]], 1)

res = partition(g, PartitionConfig(k=K, lam=0.10))
assert res.balanced

cfg = meshgraphnet.MGNConfig(n_layers=3, d_hidden=16, d_in=4)
params = meshgraphnet.init_params(cfg, jax.random.key(0))

# dense reference loss
ref_batch = {
    "graph": GraphBatch(
        node_feat=jnp.asarray(feats), senders=jnp.asarray(edges[:,0].astype(np.int32)),
        receivers=jnp.asarray(edges[:,1].astype(np.int32)), edge_feat=None,
        pos=jnp.asarray(pos), graph_id=jnp.zeros((n,), jnp.int32), n_graphs=1),
    "target": jnp.asarray(target),
}
ref_loss = float(meshgraphnet.loss_fn(cfg, params, ref_batch)[0])

# partitioned loss under shard_map on an 8-device mesh
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("data",))
n_l = 64  # 256/8 = 32; pad blocks to 64 for slack
h_cap = 64
e_cap_total = 2048
batch, stats = build_partitioned_batch(
    n, feats, pos, target, edges, res.parts, K, n_l, e_cap_total, h_cap)
assert stats["dropped_edges"] == 0, stats
assert stats["dropped_halo"] == 0, stats

arch = get_arch("meshgraphnet")
shape = {"kind": "train", "n_nodes": K*n_l, "n_edges": e_cap_total,
         "d_feat": 4, "n_graphs": 1}
arch2 = dataclasses.replace(
    arch, shapes=dict(arch.shapes, test_shape=shape),
    config=cfg, smoke=cfg)
cell = partitioned_gnn_cell(arch2, "test_shape", mesh,
                            tuning={"halo_frac": 1.0})
# align h_cap: our builder used h_cap=64 = 1.0 * n_l -> matches tuning
from repro.optim import adamw
opt = adamw.init_state(params)
step = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
               out_shardings=cell.out_shardings, donate_argnums=cell.donate)
p2, o2, metrics = step(params, opt, batch)
part_loss = float(metrics["loss"])
print("REF", ref_loss, "PART", part_loss)
assert abs(part_loss - ref_loss) / max(abs(ref_loss), 1e-9) < 1e-4, (
    ref_loss, part_loss)
print("OK")
"""


def test_partitioned_equivalence_8dev():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=600)
    assert "OK" in r.stdout, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
