"""Hypothesis property tests for the partitioner's invariants.

Includes the paper's Theorem 4.1: the slot-bucketed (approximate) eviction
prefix loses at most 2x the loss of the exact loss-ordered prefix, for
uniform vertex weights and non-negative losses.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die at collect
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import coarsen, connectivity as cn, metrics, rebalance, refine
from repro.core.graph import build_csr_host
from repro.data import graphs as gen

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def random_graph(draw, max_n=32):
    n = draw(st.integers(4, max_n))
    n_edges = draw(st.integers(n - 1, min(3 * n, n * (n - 1) // 2)))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    # spanning path guarantees connectivity
    path = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    extra = rng.integers(0, n, (n_edges, 2))
    edges = np.concatenate([path, extra])
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, 8, edges.shape[0])
    vw = rng.integers(1, 4, n)
    return build_csr_host(n, edges, w, vw)


# ---------------------------------------------------------------------------
# Theorem 4.1
# ---------------------------------------------------------------------------

@given(
    losses=st.lists(st.integers(0, 10_000), min_size=1, max_size=200),
    frac=st.floats(0.05, 0.95),
)
def test_theorem_4_1_bucketed_prefix_2x_bound(losses, frac):
    """loss(L'_x) <= 2 * loss(L_x): slot-ordered prefix vs exact prefix.

    Uniform weights, non-negative losses (the theorem's assumptions).
    """
    losses = np.asarray(losses, dtype=np.int64)
    x = max(1, int(frac * len(losses)))  # prefix size (uniform weights)
    exact = np.sort(losses)[:x]
    slots = np.asarray(rebalance.slot(jnp.asarray(losses)))
    order = np.argsort(slots, kind="stable")
    approx = losses[order][:x]
    assert approx.sum() <= 2 * exact.sum() + 0  # Thm 4.1


@given(g=random_graph(), k=st.integers(2, 6), data=st.data())
def test_refine_output_invariants(g, k, data):
    n = int(g.n)
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    parts0 = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    parts0 = jnp.where(g.vertex_mask(), parts0, k)
    lam = 0.20  # loose enough to be satisfiable with integral weights
    parts, stats = refine.jet_refine(g, parts0, k, lam=lam, max_iter=60)
    p = np.asarray(parts)
    # every real vertex assigned a real part; pads ghost
    assert p[:n].min() >= 0 and p[:n].max() < k
    assert np.all(p[n:] == k)
    # cutsize never worse than a balanced input
    W = g.total_vweight()
    sizes0 = metrics.part_sizes(g, parts0, k)
    if bool(metrics.is_balanced(sizes0, W, k, lam)):
        assert int(metrics.cutsize(g, parts)) <= int(metrics.cutsize(g, parts0))


@given(g=random_graph(), data=st.data())
def test_coarsen_conservation(g, data):
    gc, cmap = coarsen.coarsen_once(g, seed=data.draw(st.integers(0, 1000)))
    assert int(gc.total_vweight()) == int(g.total_vweight())
    m = int(g.m)
    cm = np.asarray(cmap)
    cu = cm[np.asarray(g.esrc)[:m]]
    cv = cm[np.asarray(g.adjncy)[:m]]
    w = np.asarray(g.adjwgt)[:m]
    internal = w[cu == cv].sum() // 2
    assert int(gc.total_eweight()) + internal == int(g.total_eweight())
    assert int(gc.n) <= int(g.n)


@given(g=random_graph(), k=st.integers(2, 5), data=st.data())
def test_connectivity_backends_equivalent(g, k, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    parts = jnp.asarray(rng.integers(0, k, g.n_max).astype(np.int32))
    parts = jnp.where(g.vertex_mask(), parts, k)
    qd = cn.dense_queries(g, parts, k)
    qs = cn.sorted_queries(g, parts, k)
    n = int(g.n)
    for a, b in zip(qd, qs):
        assert np.array_equal(np.asarray(a)[:n], np.asarray(b)[:n])


@given(g=random_graph(), k=st.integers(2, 5))
def test_rebalance_never_increases_max_part(g, k):
    # all vertices in part 0 -> any rebalance iteration must shrink the max
    parts = jnp.where(g.vertex_mask(), 0, k).astype(jnp.int32)
    for fn in (rebalance.jetrw_moves, rebalance.jetrs_moves):
        move, dest = fn(g, parts, k, 0.10)
        parts2 = jnp.where(move, dest, parts)
        s0 = np.asarray(metrics.part_sizes(g, parts, k))
        s2 = np.asarray(metrics.part_sizes(g, parts2, k))
        assert s2.max() <= s0.max()
        d = np.asarray(dest)[np.asarray(move)]
        if d.size:
            assert d.min() >= 0 and d.max() < k


@given(g=random_graph(max_n=24))
def test_matching_involution_property(g):
    match = coarsen.heavy_edge_matching(g)
    match = coarsen.twohop_matching(g, match)
    m = np.asarray(match)
    n = int(g.n)
    for v in range(n):
        if m[v] >= 0:
            assert m[m[v]] == v
