"""Batched best-of-N trials (DESIGN.md §9).

The load-bearing property: vmapping the uncoarsening phase over a trial
axis changes the SCHEDULE, never the VALUES — trial t of a batched run is
bit-identical to a sequential ``partition()`` run with that trial's seed,
on every backend.  Plus: device-side best-trial ordering, the fused
``uncoarsen_level`` against the legacy unfused sequence, and the
mask-aware voronoi seed guard.
"""
from dataclasses import replace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coarsen as co
from repro.core import connectivity as cn
from repro.core import initial, metrics, refine
from repro.core.graph import build_csr_host
from repro.core.partition import (
    PartitionConfig, _best_trial, partition, uncoarsen_level,
)
from repro.data import graphs as gen

TRIALS = 3


def _cfg(backend, k, **kw):
    return PartitionConfig(k=k, backend=backend, coarse_target=48,
                           max_iter=30, patience=3, **kw)


@pytest.mark.parametrize("backend", ["dense", "sorted", "ell"])
@pytest.mark.parametrize("k", [2, 8, 33])
def test_vmapped_trials_bit_identical(backend, k):
    """Batched trial t == sequential run with seed t: full parts vectors."""
    g = gen.grid2d(12, 12)
    cfg = _cfg(backend, k, trials=TRIALS)
    res = partition(g, cfg)
    assert res.trial_parts.shape == (TRIALS, g.n_max)
    for t in range(TRIALS):
        seq = partition(g, replace(cfg, trials=1, trial_seeds=(cfg.seed + t,)))
        assert res.trial_cuts[t] == seq.cut, (backend, k, t)
        assert res.trial_balanced[t] == seq.balanced
        np.testing.assert_array_equal(
            np.asarray(res.trial_parts[t]), np.asarray(seq.parts)
        )
    # the selected best is one of the trials, reported consistently
    np.testing.assert_array_equal(
        np.asarray(res.parts), np.asarray(res.trial_parts[res.best_trial])
    )
    assert res.cut == res.trial_cuts[res.best_trial]


def test_best_trial_prefers_balanced_over_lower_cut():
    """A balanced trial supersedes an unbalanced one with a lower cut."""
    bal = jnp.asarray([False, True, True, False])
    cut = jnp.asarray([10, 90, 80, 5], jnp.int32)
    msz = jnp.asarray([900, 100, 100, 950], jnp.int32)
    assert int(_best_trial(bal, cut, msz)) == 2  # lowest cut among balanced
    # no balanced trial: lowest max part weight wins, cut breaks ties
    bal0 = jnp.zeros(4, bool)
    msz2 = jnp.asarray([300, 200, 200, 400], jnp.int32)
    assert int(_best_trial(bal0, cut, msz2)) == 2  # msz tie -> cut 80 < 90
    # deterministic first-index tie-break
    assert int(_best_trial(bal0, jnp.asarray([7, 7, 7, 7], jnp.int32),
                           jnp.asarray([5, 5, 5, 5], jnp.int32))) == 0


@pytest.mark.parametrize("backend", ["dense", "sorted"])
def test_uncoarsen_level_matches_unfused(backend):
    """The fused jitted level == the legacy project/mask/build/refine
    sequence, exactly, for every trial in the batch."""
    g = gen.grid2d(16, 16)
    k = 4
    levels = co.multilevel_coarsen(g, coarse_target=64, seed=0)
    assert len(levels) >= 2
    fine, coarse = levels[-2], levels[-1]
    seeds = (0, 5)
    parts_b = initial.initial_partition_batch(coarse.graph, k, seeds)
    kw = dict(k=k, lam=0.03, c=0.75, backend=backend, patience=4,
              max_iter=40, b_max=2, variant="full", rebuild_every=0)
    fused_b, stats_b = uncoarsen_level(
        fine.graph, fine.cmap, parts_b, 0.999, **kw
    )
    for t, seed in enumerate(seeds):
        pc = initial.initial_partition(coarse.graph, k, seed=seed)
        np.testing.assert_array_equal(np.asarray(parts_b[t]), np.asarray(pc))
        # legacy unfused path: project -> mask -> build_state -> jet_refine
        pf = co.project_partition(fine.cmap, pc)
        pf = jnp.where(fine.graph.vertex_mask(), pf, k).astype(jnp.int32)
        conn0 = cn.build_state(fine.graph, pf, k, backend)
        ref, ref_stats = refine.jet_refine(
            fine.graph, pf, k, lam=0.03, c=0.75, phi=0.999, backend=backend,
            patience=4, max_iter=40, b_max=2, conn0=conn0,
        )
        np.testing.assert_array_equal(np.asarray(fused_b[t]), np.asarray(ref))
        for kk in ref_stats:
            assert int(stats_b[kk][t]) == int(ref_stats[kk]), (kk, t)


def test_voronoi_seeds_mask_aware():
    """Seeds never land on padding while real vertices remain; a k > n
    shortfall round-robins over real ids, deterministically."""
    n = 6
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    g = build_csr_host(n, edges, n_max=64, m_max=64)
    for k in (2, 4, 6):
        seeds = np.asarray(initial.spread_seeds(g, k, seed=3))
        assert seeds.shape == (k,)
        assert (seeds < n).all(), (k, seeds)
        assert len(set(seeds.tolist())) == k  # spread, not collapsed
    # shortfall: k=8 > n=6 — padded picks are replaced round-robin
    seeds = np.asarray(initial.spread_seeds(g, 8, seed=3))
    assert (seeds < n).all()
    parts = np.asarray(initial.voronoi_partition(g, 8, seed=3))
    assert (parts[:n] < 8).all() and (parts[n:] == 8).all()
    # deterministic across calls
    np.testing.assert_array_equal(
        seeds, np.asarray(initial.spread_seeds(g, 8, seed=3))
    )
    # k beyond even the PADDED capacity (k > n_max): the shortfall still
    # round-robins over real ids instead of raising a shape error
    tiny = build_csr_host(n, edges)  # n_max == n == 6
    seeds = np.asarray(initial.spread_seeds(tiny, 9, seed=3))
    assert seeds.shape == (9,) and (seeds < n).all()
    parts = np.asarray(initial.voronoi_partition(tiny, 9, seed=3))
    assert (parts[:n] < 9).all()


def test_initial_partition_batch_matches_scalar():
    g = gen.grid2d(10, 10)
    seeds = (0, 1, 7)
    for method in ("voronoi", "random"):
        batch = initial.initial_partition_batch(g, 5, seeds, method=method)
        for t, s in enumerate(seeds):
            np.testing.assert_array_equal(
                np.asarray(batch[t]),
                np.asarray(initial.initial_partition(g, 5, seed=s,
                                                     method=method)),
            )


def test_trials_one_keeps_legacy_result_shape():
    """trials=1 stays the legacy scalar contract: int stats per level."""
    g = gen.grid2d(12, 12)
    res = partition(g, _cfg("dense", 4))
    assert res.trials == 1 and res.best_trial == 0
    assert res.trial_cuts == [res.cut]
    for st in res.level_stats:
        assert isinstance(st["iterations"], int)
        assert isinstance(st["best_cost"], int)
