"""Baseline refinement variants: constrained LP + Table 3 ablation sanity."""
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, refine
from repro.core.lp_baseline import constrained_lp_refine
from repro.data import graphs as gen


def _rand_parts(g, k, seed=0):
    rng = np.random.default_rng(seed)
    p = np.full(g.n_max, k, dtype=np.int32)
    p[: int(g.n)] = rng.integers(0, k, int(g.n))
    return jnp.asarray(p)


def test_constrained_lp_improves_and_respects_balance():
    g = gen.grid2d(24, 24)
    k = 4
    lam = 0.05
    parts0 = _rand_parts(g, k, seed=4)
    cut0 = int(metrics.cutsize(g, parts0))
    parts, info = constrained_lp_refine(g, parts0, k, lam=lam)
    cut1 = int(metrics.cutsize(g, parts))
    W = g.total_vweight()
    sizes = metrics.part_sizes(g, parts, k)
    assert bool(metrics.is_balanced(sizes, W, k, lam))
    assert cut1 < cut0


def test_jet_escapes_local_minimum_where_clp_is_stuck():
    """Row-stripes on a k-divisible grid are a strict single-move local
    minimum (every vertex has F < 0): constrained LP provably cannot move,
    while Jet's afterburner admits negative-gain moves and escapes — the
    paper's central design argument (§4.1.1-4.1.2)."""
    g = gen.grid2d(24, 24)
    k = 4
    lam = 0.05
    parts0 = jnp.where(
        g.vertex_mask(), jnp.arange(g.n_max, dtype=jnp.int32) % k, k
    )
    cut0 = int(metrics.cutsize(g, parts0))
    lp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam, iters=40)
    assert int(metrics.cutsize(g, lp_parts)) == cut0  # stuck, by design
    jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
    jet_cut = int(metrics.cutsize(g, jet_parts))
    assert jet_cut < cut0, f"jet failed to escape local min: {jet_cut} vs {cut0}"


def test_jet_beats_constrained_lp_on_mesh():
    """The paper's core claim in miniature: Jet >= plain size-constrained LP."""
    g = gen.grid2d(32, 32)
    k = 4
    lam = 0.03
    parts0 = _rand_parts(g, k, seed=9)
    lp_parts, _ = constrained_lp_refine(g, parts0, k, lam=lam, iters=40)
    jet_parts, _ = refine.jet_refine(g, parts0, k, lam=lam)
    lp_cut = int(metrics.cutsize(g, lp_parts))
    jet_cut = int(metrics.cutsize(g, jet_parts))
    assert jet_cut <= lp_cut, f"jet {jet_cut} vs clp {lp_cut}"


def test_full_jetlp_beats_baseline_variant():
    """Table 3 directional check on a mesh (where the paper reports the
    largest component gains): full > baseline."""
    g = gen.grid2d(32, 32)
    k = 8
    lam = 0.03
    cuts = {}
    parts0 = _rand_parts(g, k, seed=11)
    for variant in ("baseline", "full"):
        parts, _ = refine.jet_refine(g, parts0, k, lam=lam, variant=variant)
        cuts[variant] = int(metrics.cutsize(g, parts))
    assert cuts["full"] <= cuts["baseline"], cuts
