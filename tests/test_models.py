"""Model smoke + correctness tests: LM (GQA/MLA/MoE), GNNs, recsys FM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as synth
from repro.models import transformer as tf
from repro.models.attention import chunked_attention
from repro.models.gnn import graphsage, meshgraphnet, nequip, schnet
from repro.models.gnn.common import GraphBatch
from repro.models.recsys import fm as fm_lib
from repro.kernels.flash_attention.ref import mha_ref


# ---------------------------------------------------------------------------
# attention paths agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 16])
def test_chunked_attention_matches_ref(window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 2, 64, 16)).astype(np.float32))
    want = mha_ref(q, k, v, causal=True, window=window)
    got = chunked_attention(q, k, v, causal=True, window=window, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# LM: decode == prefill for all attention kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gqa", "gqa_local", "mla"])
def test_decode_matches_prefill(kind):
    if kind == "mla":
        cfg = tf.LMConfig(
            n_layers=2, d_model=32, n_heads=2, attn_kind="mla",
            kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
            vocab=53, attn_chunk=8, remat=False, dtype="float32")
    else:
        cfg = tf.LMConfig(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab=53, attn_chunk=8, remat=False, dtype="float32",
            window=4 if kind == "gqa_local" else 0,
            local_ratio=1 if kind == "gqa_local" else 0)
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, 53)
    full, _ = tf.forward(cfg, p, toks)
    cache = tf.init_cache(cfg, 1, 8)
    outs = []
    for i in range(8):
        lg, cache = tf.decode_step(cfg, p, cache, toks[:, i])
        outs.append(np.asarray(lg))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, np.asarray(full), rtol=2e-4, atol=2e-4)


def test_lm_train_decreases_loss():
    cfg = tf.LMConfig(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                      head_dim=32, d_ff=128, vocab=64, remat=False,
                      dtype="float32", attn_chunk=32)
    p = tf.init_params(cfg, jax.random.key(0))
    data = synth.lm_batches(cfg.vocab, batch=8, seq=32)
    batch = next(data)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(
            lambda p_: tf.loss_fn(cfg, p_, batch)[0])(p)
        p = jax.tree.map(lambda a, g: a - 0.5 * g.astype(a.dtype), p, grads)
        return p, loss

    losses = []
    for _ in range(10):
        p, loss = step(p)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_moe_capacity_drops_gracefully():
    from repro.models.moe import moe_apply, moe_init
    p = moe_init(jax.random.key(0), 16, 32, 4, 1, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (32, 16))
    out, aux = moe_apply(p, x, top_k=2, capacity_factor=0.5)  # forced drops
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0


# ---------------------------------------------------------------------------
# GNNs
# ---------------------------------------------------------------------------

def test_schnet_forward_and_train():
    cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=32, n_rbf=20,
                              cutoff=3.0)
    params = schnet.init_params(cfg, jax.random.key(0))
    data = synth.molecule_batch(4, atoms=10, edges_per_graph=64)
    loss0, _ = schnet.loss_fn(cfg, params, data)
    g = jax.grad(lambda p: schnet.loss_fn(cfg, p, data)[0])(params)
    params = jax.tree.map(lambda a, gg: a - 1e-4 * gg, params, g)
    loss1, _ = schnet.loss_fn(cfg, params, data)
    assert np.isfinite(float(loss0)) and float(loss1) < float(loss0)


def test_nequip_equivariance():
    """Energy must be invariant under global rotation + translation."""
    cfg = nequip.NequipConfig(n_layers=2, d_hidden=8, n_rbf=6, cutoff=3.0)
    params = nequip.init_params(cfg, jax.random.key(0))
    data = synth.molecule_batch(2, atoms=8, edges_per_graph=48, seed=3)
    e0 = nequip.forward(cfg, params, data["graph"])
    # random rotation (QR of a gaussian) + translation
    rng = np.random.default_rng(7)
    qm, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(qm) < 0:
        qm[:, 0] *= -1
    pos2 = data["graph"].pos @ jnp.asarray(qm.astype(np.float32)) + 1.5
    batch2 = data["graph"]._replace(pos=pos2)
    e1 = nequip.forward(cfg, params, batch2)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4,
                               atol=1e-4)


def test_nequip_not_trivially_constant():
    cfg = nequip.NequipConfig(n_layers=2, d_hidden=8, n_rbf=6, cutoff=3.0)
    params = nequip.init_params(cfg, jax.random.key(0))
    d1 = synth.molecule_batch(2, atoms=8, edges_per_graph=48, seed=1)
    d2 = synth.molecule_batch(2, atoms=8, edges_per_graph=48, seed=2)
    e1 = nequip.forward(cfg, params, d1["graph"])
    e2 = nequip.forward(cfg, params, d2["graph"])
    assert not np.allclose(np.asarray(e1), np.asarray(e2))


def test_meshgraphnet_train_step():
    cfg = meshgraphnet.MGNConfig(n_layers=3, d_hidden=32)
    params = meshgraphnet.init_params(cfg, jax.random.key(0))
    data = synth.mesh_batch(8, 8)
    loss0, _ = meshgraphnet.loss_fn(cfg, params, data)
    g = jax.grad(lambda p: meshgraphnet.loss_fn(cfg, p, data)[0])(params)
    params = jax.tree.map(lambda a, gg: a - 1e-2 * gg, params, g)
    loss1, _ = meshgraphnet.loss_fn(cfg, params, data)
    assert float(loss1) < float(loss0)


def test_graphsage_with_sampler_learns():
    edges, feats, labels = synth.community_graph(n=400, n_classes=4,
                                                 d_feat=32, seed=0)
    cfg = graphsage.SageConfig(n_layers=2, d_in=32, d_hidden=32, n_classes=4)
    params = graphsage.init_params(cfg, jax.random.key(0))
    sampler = synth.NeighborSampler(edges, 400, fanouts=(10, 5))
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: graphsage.loss_fn(cfg, p, batch)[0])(params)
        return jax.tree.map(lambda a, g: a - 0.3 * g, params, grads), loss

    losses = []
    for i in range(20):
        seeds = rng.choice(400, 64, replace=False)
        batch = sampler.sample(seeds, feats, labels, pad_nodes=2048,
                               pad_edges=8192)
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < 0.7 * np.mean(losses[:5]), losses


# ---------------------------------------------------------------------------
# recsys FM
# ---------------------------------------------------------------------------

def test_fm_learns_planted_rule():
    cfg = fm_lib.FMConfig(n_fields=8, embed_dim=8, rows_per_field=32)
    params = fm_lib.init_params(cfg, jax.random.key(0))
    data = synth.recsys_batches(8, 32, batch=512, seed=0)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: fm_lib.loss_fn(cfg, p, batch)[0])(params)
        return jax.tree.map(lambda a, g: a - 1.0 * g.astype(a.dtype),
                            params, grads), loss

    losses = []
    for i in range(60):
        params, loss = step(params, next(data))
        losses.append(float(loss))
    assert losses[-1] < 0.8 * losses[0], (losses[0], losses[-1])


def test_fm_retrieval_matches_manual():
    cfg = fm_lib.FMConfig(n_fields=4, embed_dim=8, rows_per_field=32)
    params = fm_lib.init_params(cfg, jax.random.key(1))
    user = jnp.asarray([[3, 7, 11]], dtype=jnp.int32)
    cands = jnp.arange(16, dtype=jnp.int32)
    scores = fm_lib.retrieval_scores(cfg, params, user, cands)
    assert scores.shape == (16,)
    # manual check for candidate 5
    tbl = np.asarray(params["table"], dtype=np.float32)
    off = np.arange(4) * 32
    u_vec = tbl[[3 + off[0], 7 + off[1], 11 + off[2]]].sum(0)
    c_emb = tbl[5 + off[3]]
    want = u_vec @ c_emb + float(np.asarray(params["linear"])[5 + off[3]])
    np.testing.assert_allclose(float(scores[5]), want, rtol=1e-4)
