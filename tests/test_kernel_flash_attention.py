"""flash_attention kernel vs oracle — GQA/causal/window/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_ref


def _qkv(b, h, hkv, sq, skv, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, h, sq, d)).astype(dtype)
    k = rng.standard_normal((b, hkv, skv, d)).astype(dtype)
    v = rng.standard_normal((b, hkv, skv, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("b,h,hkv,sq,skv,d,bq,bk", [
    (1, 2, 2, 128, 128, 32, 64, 64),     # MHA, square
    (2, 4, 1, 128, 128, 16, 64, 64),     # MQA
    (1, 8, 2, 256, 256, 64, 128, 128),   # GQA 4:1
    (1, 2, 2, 64, 256, 32, 64, 64),      # cross lengths (chunked prefill)
    (2, 2, 1, 128, 128, 8, 32, 128),     # asymmetric blocks
])
def test_flash_matches_ref_causal(b, h, hkv, sq, skv, d, bq, bk):
    q, k, v = _qkv(b, h, hkv, sq, skv, d, np.float32, seed=sq + d)
    off = skv - sq  # align causal diag to the end (prefill continuation)
    want = mha_ref(q, k, v, causal=True, q_offset=off)
    got = flash_attention_pallas(q, k, v, causal=True, q_offset=off,
                                 bq=bq, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(1, 2, 2, 128, 128, 32, np.float32, seed=1)
    want = mha_ref(q, k, v, causal=False)
    got = flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_sliding_window(window):
    q, k, v = _qkv(1, 2, 1, 128, 128, 32, np.float32, seed=window)
    want = mha_ref(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 4, 2, 128, 128, 64, np.float32, seed=9)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    want = mha_ref(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_flash_decode_shape():
    # one query against a long cache (sq=1 padded to block internally? no:
    # bq=min(bq, sq)=1) — decode path
    q, k, v = _qkv(2, 4, 2, 1, 256, 32, np.float32, seed=3)
    want = mha_ref(q, k, v, causal=True, q_offset=255)
    got = flash_attention_pallas(q, k, v, causal=True, q_offset=255,
                                 bq=1, bk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
