"""segment_reduce kernel vs oracle — shape/dtype sweeps incl. straddling runs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.segment_reduce.ops import segment_sum_sorted
from repro.kernels.segment_reduce.ref import segment_sum_sorted_ref


def _case(m, f, s, seed, dtype, skewed=False):
    rng = np.random.default_rng(seed)
    if skewed:  # one giant segment straddling many blocks
        seg = np.sort(rng.choice([0, s // 2, s - 1], m, p=[0.8, 0.1, 0.1]))
    else:
        seg = np.sort(rng.integers(0, s, m))
    data = rng.standard_normal((m, f)).astype(dtype)
    if dtype in (np.int32,):
        data = rng.integers(-5, 5, (m, f)).astype(dtype)
    return jnp.asarray(data), jnp.asarray(seg.astype(np.int32))


@pytest.mark.parametrize("m,f,s,block", [
    (512, 8, 32, 128),
    (1024, 16, 200, 256),
    (300, 4, 10, 128),     # needs padding
    (256, 128, 256, 64),   # every row its own segment
    (2048, 32, 3, 512),    # giant segments straddle blocks
])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_segment_sum_sweep(m, f, s, block, dtype):
    data, seg = _case(m, f, s, seed=m + f, dtype=dtype)
    want = segment_sum_sorted_ref(data, seg, s)
    got = segment_sum_sorted(data, seg, s, block_m=block)
    if dtype == np.float32:
        # fp32 accumulation order differs (blocked vs sequential); the kernel
        # is *closer* to the float64 truth than the oracle on long segments.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                                   atol=1e-3)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_sum_empty_segments_and_padding_rows():
    # segment ids skip values; some rows marked dropped (seg >= S)
    seg = jnp.asarray([0, 0, 5, 5, 5, 9, 12, 12], dtype=jnp.int32)
    data = jnp.arange(16, dtype=jnp.float32).reshape(8, 2)
    want = segment_sum_sorted_ref(data, seg, 10)  # ids 12 dropped
    got = segment_sum_sorted(data, seg, 10, block_m=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert np.all(np.asarray(got)[1:5] == 0)  # empty segments stay zero


def test_segment_sum_skewed():
    data, seg = _case(1024, 8, 64, seed=7, dtype=np.float32, skewed=True)
    want = segment_sum_sorted_ref(data, seg, 64)
    got = segment_sum_sorted(data, seg, 64, block_m=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
