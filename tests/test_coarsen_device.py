"""Device-resident coarsening (DESIGN.md §8): equivalence with the legacy
host-repack path, shape-schedule mechanics, and capacity re-bucketing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coarsen
from repro.core.graph import csr_from_edge_runs, validate_host
from repro.core.partition import PartitionConfig, partition
from repro.data import graphs as gen

FAMILIES = ["grid_64x32", "rmat_12", "smallworld_4k"]


def test_coarsen_level_traces_with_no_host_transfers():
    """The whole level — matching, two-hop cond, contraction, CSR build —
    must stage to one pure jaxpr: any host sync would fail tracing."""
    g = gen.suite_graph("grid_64x32")
    jaxpr = jax.make_jaxpr(
        lambda gg, s: coarsen.coarsen_level(gg, seed=s)
    )(g, jnp.int32(0))
    assert "callback" not in str(jaxpr)


@pytest.mark.parametrize("name", FAMILIES)
def test_device_hierarchy_matches_host(name):
    g = gen.suite_graph(name)
    dev = coarsen.multilevel_coarsen(g, coarse_target=256, mode="device")
    host = coarsen.multilevel_coarsen(g, coarse_target=256, mode="host")
    assert len(dev) == len(host) and len(dev) >= 2
    for a, b in zip(dev, host):
        # same true sizes, same tight content — padding may differ
        assert (a.stats["n"], a.stats["m"]) == (b.stats["n"], b.stats["m"])
        n, m = a.stats["n"], a.stats["m"]
        for f in ("esrc", "adjncy", "adjwgt"):
            assert np.array_equal(np.asarray(getattr(a.graph, f))[:m],
                                  np.asarray(getattr(b.graph, f))[:m]), f
        assert np.array_equal(np.asarray(a.graph.vwgt)[:n],
                              np.asarray(b.graph.vwgt)[:n])
        assert np.array_equal(np.asarray(a.graph.xadj)[: n + 1],
                              np.asarray(b.graph.xadj)[: n + 1])
        validate_host(a.graph)
        if a.cmap is not None:
            assert np.array_equal(np.asarray(a.cmap)[:n_prev(a)],
                                  np.asarray(b.cmap)[:n_prev(a)])


def n_prev(level):
    return level.stats["n"]


@pytest.mark.parametrize("name", FAMILIES)
def test_partition_cut_matches_host(name):
    g = gen.suite_graph(name)
    cuts = {}
    for mode in ("device", "host"):
        cfg = PartitionConfig(k=8, coarse_target=256, max_iter=60,
                              patience=6, coarsen_mode=mode)
        cuts[mode] = partition(g, cfg).cut
    assert cuts["device"] == cuts["host"], cuts


def test_device_levels_shrink_capacity():
    g = gen.suite_graph("grid_64x32")
    dev = coarsen.multilevel_coarsen(g, coarse_target=128, mode="device")
    caps = [(lv.stats["n_max"], lv.stats["m_max"]) for lv in dev]
    assert caps[-1][0] < caps[0][0] and caps[-1][1] < caps[0][1], caps
    for lv in dev:
        assert lv.stats["n"] <= lv.stats["n_max"]
        assert lv.stats["m"] <= lv.stats["m_max"]


def test_shape_schedule_rungs():
    sched = coarsen.shape_schedule(10000, 80000)
    assert sched[0] == (10000, 80000)
    # descending in both coordinates, aligned past rung 0
    for (n0, m0), (n1, m1) in zip(sched, sched[1:]):
        assert n1 <= n0 and m1 <= m0
        assert n1 % 64 == 0 and m1 % 64 == 0
    # selection: per-axis smallest fitting rung, top rung always fits
    assert coarsen.select_capacity(sched, 10000, 80000) == sched[0]
    cap = coarsen.select_capacity(sched, 100, 700)
    assert cap[0] >= 100 and cap[1] >= 700
    assert cap[0] == min(n for n, _ in sched if n >= 100)
    assert cap[1] == min(m for _, m in sched if m >= 700)


def test_undersized_schedule_rejected():
    g = gen.suite_graph("grid_64x32")  # n=2048
    bad = coarsen.shape_schedule(256, 1024)
    with pytest.raises(ValueError, match="rung 0"):
        coarsen.multilevel_coarsen(g, mode="device", schedule=bad)


def test_with_capacity_roundtrip():
    g = gen.suite_graph("grid_64x32")
    big = g.with_capacity(g.n_max + 100, g.m_max + 256)
    assert big.n_max == g.n_max + 100 and big.m_max == g.m_max + 256
    validate_host(big)
    back = big.with_capacity(g.n_max, g.m_max)
    for f in g._fields:
        assert np.array_equal(np.asarray(getattr(back, f)),
                              np.asarray(getattr(g, f))), f


def test_csr_from_edge_runs_matches_contract():
    """Device CSR constructor reproduces what the host repack builds."""
    g = gen.suite_graph("cube_12")
    gc_host, cmap = coarsen.coarsen_once(g, seed=3)
    cu, cv, w, valid, n_runs, vwgt_c = coarsen.contract_edges(g, cmap)
    gc_dev = csr_from_edge_runs(cu, cv, w, valid, n_runs, vwgt_c,
                                jnp.asarray(int(gc_host.n), jnp.int32),
                                n_max=g.n_max, m_max=g.m_max)
    validate_host(gc_dev)
    n, m = int(gc_host.n), int(gc_host.m)
    assert int(gc_dev.n) == n and int(gc_dev.m) == m
    assert np.array_equal(np.asarray(gc_dev.xadj)[: n + 1],
                          np.asarray(gc_host.xadj)[: n + 1])
    for f in ("esrc", "adjncy", "adjwgt"):
        assert np.array_equal(np.asarray(getattr(gc_dev, f))[:m],
                              np.asarray(getattr(gc_host, f))[:m]), f
