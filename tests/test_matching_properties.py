"""Matching invariants (paper §3.1) across seeds and graph shapes.

Both matching stages must produce a symmetric partial matching
(``match[match[v]] == v``), never self-match a real vertex, and the two-hop
pass may only touch previously-unmatched vertices.
"""
import numpy as np
import pytest

from repro.core import coarsen
from repro.data import graphs as gen

SHAPES = ["grid_64x32", "cube_12", "rmat_12", "smallworld_4k"]
SEEDS = [0, 1, 7]


def _invariants(g, match):
    n = int(g.n)
    m = np.asarray(match)[:n]
    matched = m >= 0
    # in-range partners
    assert np.all(m[matched] < n)
    # no self-match for real vertices
    assert np.all(m[matched] != np.arange(n)[matched])
    # symmetric: match[match[v]] == v
    assert np.array_equal(m[m[matched]], np.arange(n)[matched])


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("name", SHAPES)
def test_hem_invariants(name, seed):
    g = gen.suite_graph(name)
    match = coarsen.heavy_edge_matching(g, seed=seed)
    _invariants(g, match)
    # HEM matches are along edges: partner must be a neighbor
    n = int(g.n)
    m = np.asarray(match)[:n]
    xadj = np.asarray(g.xadj)
    adjncy = np.asarray(g.adjncy)
    for v in np.flatnonzero(m >= 0)[:64]:
        assert m[v] in adjncy[xadj[v]: xadj[v + 1]], (v, m[v])


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("name", SHAPES)
def test_twohop_invariants(name, seed):
    g = gen.suite_graph(name)
    before = coarsen.heavy_edge_matching(g, seed=seed)
    after = coarsen.twohop_matching(g, before, 64, seed)
    _invariants(g, after)
    # only previously-unmatched vertices change
    n = int(g.n)
    b = np.asarray(before)[:n]
    a = np.asarray(after)[:n]
    already = b >= 0
    assert np.array_equal(a[already], b[already])


def test_twohop_seed_decorrelates():
    """The satellite fix: twin/relative tie-break hashes take the level seed,
    so different levels pair differently instead of identically."""
    g = gen.suite_graph("rmat_12")
    match = coarsen.heavy_edge_matching(g, seed=0)
    outs = [np.asarray(coarsen.twohop_matching(g, match, 64, s))[: int(g.n)]
            for s in (0, 1, 2)]
    assert any(not np.array_equal(outs[0], o) for o in outs[1:]), (
        "two-hop pairing identical across seeds — seed not plumbed through"
    )
    for o in outs:
        matched = o >= 0
        assert np.array_equal(o[o[matched]], np.arange(int(g.n))[matched])
