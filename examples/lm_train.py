"""End-to-end training driver: train a small LM for a few hundred steps with
the full production loop (AdamW + schedule, checkpointing, watchdog).

    PYTHONPATH=src python examples/lm_train.py [--steps 300]
"""
import argparse

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    train_launch.main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--ckpt-dir", "/tmp/repro_lm_train_example",
    ])


if __name__ == "__main__":
    main()
