"""Quickstart: partition a graph with the Jet partitioner.

    PYTHONPATH=src python examples/quickstart.py [--k 8] [--graph grid]
"""
import argparse

import numpy as np

from repro.core.metrics import cutsize
from repro.core.partition import PartitionConfig, partition
from repro.data import graphs as gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="grid", choices=["grid", "cube", "rmat", "geo"])
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--imbalance", type=float, default=0.03)
    ap.add_argument("--size", type=int, default=64)
    args = ap.parse_args()

    if args.graph == "grid":
        g = gen.grid2d(args.size, args.size)
    elif args.graph == "cube":
        g = gen.grid3d(args.size // 4, args.size // 4, args.size // 4)
    elif args.graph == "rmat":
        g = gen.rmat(scale=12)
    else:
        g = gen.random_geometric(args.size * args.size)

    print(f"graph: n={int(g.n)} m={int(g.m)//2} (undirected)")
    cfg = PartitionConfig(k=args.k, lam=args.imbalance)
    res = partition(g, cfg)

    print(f"k={args.k} lambda={args.imbalance}")
    print(f"  cutsize    : {res.cut}")
    print(f"  imbalance  : {res.imbalance:.4f} (balanced={res.balanced})")
    print(f"  levels     : {res.levels}")
    for name, t in res.times.items():
        print(f"  {name:<12}: {t:.3f}")
    # vs random baseline
    rng = np.random.default_rng(0)
    import jax.numpy as jnp

    rand = jnp.where(
        g.vertex_mask(),
        jnp.asarray(rng.integers(0, args.k, g.n_max).astype(np.int32)),
        args.k,
    )
    print(f"  random cut : {int(cutsize(g, rand))}  (for scale)")


if __name__ == "__main__":
    main()
