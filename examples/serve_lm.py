"""Batched LM serving demo: prefill + greedy decode with a KV cache
(MLA archs use the compressed-cache absorbed-projection path).

    PYTHONPATH=src python examples/serve_lm.py [--arch deepseek-v2-lite-16b]
"""
import argparse

from repro.launch import serve as serve_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    args = ap.parse_args()
    serve_launch.main(["--arch", args.arch, "--batch", "4",
                       "--prompt-len", "24", "--gen", "12"])


if __name__ == "__main__":
    main()
