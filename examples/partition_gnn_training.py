"""The paper's technique as a distribution substrate: Jet-partition a graph,
lay it out across (virtual) devices, and train GraphSAGE — reporting the
collective-traffic reduction the partitioner buys per message-passing layer.

    PYTHONPATH=src python examples/partition_gnn_training.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionConfig, partition
from repro.core.graph import build_csr_host
from repro.data import synthetic as synth
from repro.dist.partition_aware import (
    comm_bytes_per_layer, naive_plan, plan_from_partition,
)
from repro.models.gnn import graphsage
from repro.models.gnn.common import GraphBatch


def mesh_showcase(k_devices=8):
    """Mesh-structured graph (typical FEM/simulation workload): this is
    where the partitioner's halo reduction is dramatic."""
    from repro.data import graphs as gen

    g = gen.grid2d(64, 64)
    res = partition(g, PartitionConfig(k=k_devices, lam=0.05))
    jet = plan_from_partition(g, res.parts, k_devices)
    naive = naive_plan(g, k_devices)
    cbn = comm_bytes_per_layer(naive, 128)
    cbj = comm_bytes_per_layer(jet, 128)
    print(f"mesh 64x64 across {k_devices} devices:")
    print(f"  local edges: naive {naive.local_edge_frac:.1%} -> "
          f"jet {jet.local_edge_frac:.1%}")
    print(f"  halo vertices: naive {naive.halo_fraction:.1%} -> "
          f"jet {jet.halo_fraction:.1%}")
    print(f"  per-layer comm: {cbn['naive_allgather']/1e6:.2f} MB -> "
          f"{cbj['partition_halo']/1e6:.3f} MB "
          f"({cbn['naive_allgather']/max(cbj['partition_halo'],1):.0f}x less)")


def main():
    mesh_showcase()

    n, n_classes, d_feat = 1200, 8, 64
    edges, feats, labels = synth.community_graph(
        n=n, n_classes=n_classes, d_feat=d_feat, seed=0)
    g = build_csr_host(n, edges)

    k_devices = 8
    print(f"\nSBM graph: n={n} m={int(g.m)//2}; partitioning for "
          f"{k_devices} devices")
    res = partition(g, PartitionConfig(k=k_devices, lam=0.05))
    print(f"  jet cut={res.cut} imbalance={res.imbalance:.3f}")

    jet = plan_from_partition(g, res.parts, k_devices)
    naive = naive_plan(g, k_devices)
    print(f"  local edges: naive {naive.local_edge_frac:.1%} -> "
          f"jet {jet.local_edge_frac:.1%}")
    print(f"  halo vertices: naive {naive.halo_fraction:.1%} -> "
          f"jet {jet.halo_fraction:.1%}")
    cb_naive = comm_bytes_per_layer(naive, 128)
    cb_jet = comm_bytes_per_layer(jet, 128)
    print(f"  per-layer comm: all-gather {cb_naive['naive_allgather']/1e6:.2f} MB"
          f" -> halo {cb_jet['partition_halo']/1e6:.2f} MB "
          f"({cb_jet['reduction']:.1f}x less)")

    # train on the REORDERED graph (device-contiguous vertex blocks)
    perm = jet.perm
    e_new = jet.edges_new
    batch = {
        "graph": GraphBatch(
            node_feat=jnp.asarray(feats[perm]),
            senders=jnp.asarray(e_new[:, 0].astype(np.int32)),
            receivers=jnp.asarray(e_new[:, 1].astype(np.int32)),
            edge_feat=None,
            pos=jnp.zeros((n, 3), jnp.float32),
            graph_id=jnp.zeros((n,), jnp.int32),
            n_graphs=1,
        ),
        "labels": jnp.asarray(labels[perm].astype(np.int32)),
    }
    cfg = graphsage.SageConfig(n_layers=2, d_in=d_feat, d_hidden=64,
                               n_classes=n_classes)
    params = graphsage.init_params(cfg, jax.random.key(0))

    @jax.jit
    def step(params):
        loss, grads = jax.value_and_grad(
            lambda p: graphsage.loss_fn(cfg, p, batch)[0])(params)
        return jax.tree.map(lambda a, g_: a - 0.5 * g_, params, grads), loss

    for i in range(40):
        params, loss = step(params)
        if (i + 1) % 10 == 0:
            print(f"  step {i+1}: loss {float(loss):.4f}")
    logits = graphsage.forward(cfg, params, batch["graph"])
    acc = float(jnp.mean((jnp.argmax(logits, -1) == batch["labels"])))
    print(f"  final train accuracy: {acc:.1%}")


if __name__ == "__main__":
    main()
