"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff=1408(expert) vocab=102400, MLA kv_lora=512,
MoE 64 routed experts top-6 + 2 shared.  Pure full attention (MLA) ->
long_500k skipped.  (The assignment text lists both "64e top-6" and
"160 routed"; we follow the headline 64e top-6 + 2 shared, which matches
the released V2-Lite checkpoint.)
"""
from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LMConfig

ARCH = Arch(
    id="deepseek-v2-lite-16b",
    family="lm",
    source="arXiv:2405.04434",
    config=LMConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        vocab=102400,
        attn_kind="mla",
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        moe=True,
        n_experts=64,
        n_shared=2,
        top_k=6,
        d_expert=1408,
        d_ff=1408,
        rope_theta=10_000.0,
        dtype="bfloat16",
    ),
    smoke=LMConfig(
        name="deepseek-v2-lite-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        attn_kind="mla",
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        moe=True,
        n_experts=8,
        n_shared=2,
        top_k=2,
        d_expert=48,
        d_ff=48,
        vocab=512,
        dtype="float32",
        remat=False,
        attn_chunk=32,
    ),
    shapes=lm_shapes(long_ok=False),
    skip_notes={"long_500k": "pure full-attention stack (assignment: skip)"},
)
