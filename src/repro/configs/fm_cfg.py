"""Factorization Machine [Rendle ICDM'10]: 39 sparse fields, embed_dim=10,
pairwise interactions via the O(nk) sum-square trick (fused Pallas kernel).
"""
from repro.configs.base import Arch
from repro.models.recsys.fm import FMConfig

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

ARCH = Arch(
    id="fm",
    family="recsys",
    source="Rendle ICDM'10",
    config=FMConfig(n_fields=39, embed_dim=10, rows_per_field=262144),
    smoke=FMConfig(n_fields=8, embed_dim=8, rows_per_field=64),
    shapes=dict(RECSYS_SHAPES),
)
