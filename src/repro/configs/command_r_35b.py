"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias.
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LMConfig

ARCH = Arch(
    id="command-r-35b",
    family="lm",
    source="hf:CohereForAI/c4ai-command-r-v01",
    config=LMConfig(
        name="command-r-35b",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab=256000,
        rope_theta=8_000_000.0,
        dtype="bfloat16",
    ),
    smoke=LMConfig(
        name="command-r-smoke",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=1,
        head_dim=16,
        d_ff=352,
        vocab=512,
        dtype="float32",
        remat=False,
        attn_chunk=64,
    ),
    shapes=lm_shapes(long_ok=False),
    skip_notes={"long_500k": "pure full-attention stack (assignment: skip)"},
)
