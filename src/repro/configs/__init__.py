"""Architecture registry: 10 assigned archs + the paper's own partitioner.

Each arch module exports ``ARCH`` (see configs/base.py for the schema).
"""
from __future__ import annotations

from importlib import import_module

_MODULES = {
    "command-r-35b": "repro.configs.command_r_35b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b",
    "schnet": "repro.configs.schnet_cfg",
    "nequip": "repro.configs.nequip_cfg",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "meshgraphnet": "repro.configs.meshgraphnet_cfg",
    "fm": "repro.configs.fm_cfg",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return import_module(_MODULES[arch_id]).ARCH
