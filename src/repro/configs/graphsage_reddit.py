"""GraphSAGE-Reddit [arXiv:1706.02216]: 2 layers, 128 hidden, mean agg,
sample sizes 25-10 (the minibatch_lg shape samples with the assigned 15-10)."""
from repro.configs.base import Arch
from repro.configs.gnn_shapes import GNN_SHAPES
from repro.models.gnn.graphsage import SageConfig

ARCH = Arch(
    id="graphsage-reddit",
    family="gnn",
    source="arXiv:1706.02216",
    config=SageConfig(n_layers=2, d_in=602, d_hidden=128, n_classes=41,
                      aggregator="mean", sample_sizes=(25, 10)),
    smoke=SageConfig(n_layers=2, d_in=32, d_hidden=16, n_classes=4,
                     sample_sizes=(5, 5)),
    shapes=dict(GNN_SHAPES),
)
