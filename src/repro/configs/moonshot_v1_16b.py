"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
(+2 shared, DeepSeek-V3-style arch).  Pure full attention -> long_500k
skipped.
"""
from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LMConfig

ARCH = Arch(
    id="moonshot-v1-16b-a3b",
    family="lm",
    source="hf:moonshotai/Moonlight-16B-A3B",
    config=LMConfig(
        name="moonshot-v1-16b-a3b",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        vocab=163840,
        moe=True,
        n_experts=64,
        n_shared=2,
        top_k=6,
        d_expert=1408,
        d_ff=1408,
        rope_theta=50_000.0,
        dtype="bfloat16",
    ),
    smoke=LMConfig(
        name="moonshot-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        moe=True,
        n_experts=8,
        n_shared=2,
        top_k=2,
        d_expert=48,
        d_ff=48,
        vocab=512,
        dtype="float32",
        remat=False,
        attn_chunk=32,
    ),
    shapes=lm_shapes(long_ok=False),
    skip_notes={"long_500k": "pure full-attention stack (assignment: skip)"},
)
