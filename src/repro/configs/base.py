"""Arch descriptor schema shared by all config modules.

ARCH = Arch(
    id         = "command-r-35b",
    family     = "lm" | "gnn" | "recsys",
    config     = <model config dataclass, full published dims>,
    smoke      = <reduced config of the same family>,
    shapes     = {shape_name: <shape dict>},   # value None => skipped cell
    skip_notes = {shape_name: "why"},
)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Arch:
    id: str
    family: str
    config: Any
    smoke: Any
    shapes: dict
    skip_notes: dict = field(default_factory=dict)
    source: str = ""


LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")


def lm_shapes(long_ok: bool):
    shapes = {
        "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
        "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
        "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    }
    if long_ok:
        shapes["long_500k"] = {"kind": "decode", "seq": 524288, "batch": 1}
    else:
        shapes["long_500k"] = None
    return shapes
