"""MeshGraphNet [arXiv:2010.03409]: 15 layers, 128 hidden, sum agg, 2-layer MLPs."""
from repro.configs.base import Arch
from repro.configs.gnn_shapes import GNN_SHAPES
from repro.models.gnn.meshgraphnet import MGNConfig

ARCH = Arch(
    id="meshgraphnet",
    family="gnn",
    source="arXiv:2010.03409",
    config=MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2),
    smoke=MGNConfig(n_layers=3, d_hidden=32, mlp_layers=2),
    shapes=dict(GNN_SHAPES),
)
