"""SchNet [arXiv:1706.08566]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10."""
from repro.configs.base import Arch
from repro.configs.gnn_shapes import GNN_SHAPES
from repro.models.gnn.schnet import SchNetConfig

ARCH = Arch(
    id="schnet",
    family="gnn",
    source="arXiv:1706.08566",
    config=SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0),
    smoke=SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=16, cutoff=3.0),
    shapes=dict(GNN_SHAPES),
)
