"""InternLM2 20B [arXiv:2403.17297; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LMConfig

ARCH = Arch(
    id="internlm2-20b",
    family="lm",
    source="arXiv:2403.17297",
    config=LMConfig(
        name="internlm2-20b",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=92544,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
    ),
    smoke=LMConfig(
        name="internlm2-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab=512,
        dtype="float32",
        remat=False,
        attn_chunk=64,
    ),
    shapes=lm_shapes(long_ok=False),
    skip_notes={"long_500k": "pure full-attention stack (assignment: skip)"},
)
