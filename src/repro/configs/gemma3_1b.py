"""Gemma-3 1B [hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global
sliding-window attention (window 512), 128k+ context.  Hybrid attention ->
long_500k RUNS for this arch (only 1-in-6 layers pay O(S) at decode).
"""
from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LMConfig

ARCH = Arch(
    id="gemma3-1b",
    family="lm",
    source="hf:google/gemma-3-1b-pt",
    config=LMConfig(
        name="gemma3-1b",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab=262144,
        window=512,
        local_ratio=5,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
    ),
    smoke=LMConfig(
        name="gemma3-smoke",
        n_layers=6,
        d_model=96,
        n_heads=4,
        n_kv_heads=1,
        head_dim=24,
        d_ff=192,
        vocab=512,
        window=16,
        local_ratio=5,
        dtype="float32",
        remat=False,
        attn_chunk=32,
    ),
    shapes=lm_shapes(long_ok=True),
)
