"""The four assigned GNN input shapes (shared by all 4 GNN archs).

Numbers are taken verbatim from the assignment; n_edges is treated as the
directed-edge array length.  ``minibatch_lg`` describes the *sampled batch*
(padded shapes) plus the full-graph stats the neighbor sampler draws from.
"""

GNN_SHAPES = {
    "full_graph_sm": {
        "kind": "train", "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
        "n_graphs": 1,
    },
    "minibatch_lg": {
        "kind": "train", "pad_nodes": 196608, "pad_edges": 262144,
        "d_feat": 602, "n_graphs": 1, "full_nodes": 232965,
        "full_edges": 114_615_892, "batch_nodes": 1024, "fanout": (15, 10),
    },
    "ogb_products": {
        "kind": "train", "n_nodes": 2_449_029, "n_edges": 61_859_140,
        "d_feat": 100, "n_graphs": 1,
    },
    "molecule": {
        "kind": "train", "n_nodes": 30 * 128, "n_edges": 64 * 128,
        "d_feat": 64, "n_graphs": 128, "atoms": 30,
    },
}
