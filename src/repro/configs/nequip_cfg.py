"""NequIP [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 rbf, cutoff 5.

E(3)-equivariant; tensor products realized as closed-form l<=2 covariant
products (DESIGN.md §6).
"""
from repro.configs.base import Arch
from repro.configs.gnn_shapes import GNN_SHAPES
from repro.models.gnn.nequip import NequipConfig

ARCH = Arch(
    id="nequip",
    family="gnn",
    source="arXiv:2101.03164",
    config=NequipConfig(n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0),
    smoke=NequipConfig(n_layers=2, d_hidden=8, l_max=2, n_rbf=6, cutoff=3.0),
    shapes=dict(GNN_SHAPES),
)
