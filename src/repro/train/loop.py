"""Fault-tolerant training loop.

Production behaviours implemented and tested:
  * checkpoint/restart — periodic atomic checkpoints; ``run()`` resumes from
    the latest one (bitwise-identical optimizer state), so a killed process
    (or preempted node) continues where it stopped;
  * failure injection — ``fail_at_step`` simulates a node crash in tests;
  * straggler watchdog — per-step wall time vs a moving average; steps
    slower than ``straggler_factor`` x EMA are counted and surfaced (on a
    real fleet this feeds the rescheduler; here it is observable state);
  * elastic restart — checkpoints store full logical arrays; on resume the
    caller may pass different shardings (see checkpoint.restore);
  * optional int8 error-feedback gradient compression (optim/compression).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.optim import adamw, compression
from repro.train import checkpoint as ckpt


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    resume: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    fail_at_step: int = -1          # failure injection (tests)
    compress_grads: bool = False


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int


def build_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                     compress: bool = False):
    """loss_fn(params, batch) -> (loss, metrics). Returns jitted step fn."""

    def step(params, opt_state, err, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if compress:
            payload, scales, err = compression.compress(grads, err)
            grads = compression.decompress(payload, scales)
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, err, {
            "loss": loss, **metrics, **opt_metrics}

    return jax.jit(step, donate_argnums=(0, 1, 2))


def run(cfg: TrainLoopConfig, state: TrainState, train_step,
        data: Iterator, err=None, log=print) -> TrainState:
    """Run (or resume) the loop. Returns the final state."""
    start_step = state.step
    if cfg.resume:
        latest = ckpt.latest_step(cfg.ckpt_dir)
        if latest is not None and latest > state.step:
            tree = ckpt.restore(
                cfg.ckpt_dir, latest,
                {"params": state.params, "opt": state.opt_state})
            state = TrainState(tree["params"], tree["opt"], latest)
            start_step = latest
            log(f"[loop] resumed from step {latest}")
    if err is None:
        err = compression.init_error(state.params) if cfg.compress_grads \
            else jnp.zeros(())

    ema = None
    stragglers = 0
    history = []
    params, opt_state = state.params, state.opt_state
    for step_i in range(start_step, cfg.total_steps):
        if step_i == cfg.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step_i}")
        batch = next(data)
        t0 = time.perf_counter()
        params, opt_state, err, metrics = train_step(
            params, opt_state, err, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if ema is None:
            ema = dt
        if dt > cfg.straggler_factor * ema and step_i > start_step + 2:
            stragglers += 1
            log(f"[watchdog] step {step_i} took {dt:.3f}s "
                f"({dt/ema:.1f}x EMA) — straggler #{stragglers}")
        ema = cfg.ema_decay * ema + (1 - cfg.ema_decay) * dt
        history.append(float(metrics["loss"]))
        if (step_i + 1) % cfg.log_every == 0:
            log(f"[loop] step {step_i+1} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics.get('lr', 0)):.2e} {dt*1e3:.0f}ms")
        if (step_i + 1) % cfg.ckpt_every == 0 or step_i + 1 == cfg.total_steps:
            ckpt.save(cfg.ckpt_dir, step_i + 1,
                      {"params": params, "opt": opt_state},
                      extra={"loss": history[-1], "stragglers": stragglers})
    return TrainState(params, opt_state, cfg.total_steps)
