"""Sharded, atomic, elastic checkpointing.

Format: a directory ``step_<N>/`` containing ``arrays.npz`` (flattened
pytree leaves keyed by path) + ``manifest.json`` (step, shapes, dtypes,
mesh metadata).  Writes go to ``.tmp-<pid>`` then ``os.replace`` — a crash
mid-write never corrupts the latest checkpoint.  Restore is *elastic*:
arrays are saved in full logical shape, so a restart may use a different
device count/mesh; the caller re-shards with its own NamedSharding.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "process_count": jax.process_count(),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and ".tmp-" not in d]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Rebuild ``target_tree``-shaped pytree from disk.

    ``shardings``: optional matching pytree of jax.sharding.Sharding for
    elastic re-sharding onto the current mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for (kpath, leaf), shard in zip(flat[0], shard_leaves):
        key = "/".join(str(p) for p in kpath)
        arr = data[key]
        assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
        if shard is not None:
            new_leaves.append(jax.device_put(arr.astype(leaf.dtype), shard))
        else:
            new_leaves.append(np.asarray(arr).astype(leaf.dtype))
    import jax.numpy as jnp

    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(x) for x in new_leaves])


def read_manifest(ckpt_dir: str, step: int) -> dict:
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)
