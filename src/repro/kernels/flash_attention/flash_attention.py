"""Pallas TPU kernel: FlashAttention-style blocked online-softmax attention.

Grid (BH, num_q_blocks, num_kv_blocks), kv innermost so the (acc, m, l)
running state lives in VMEM scratch across kv steps.  GQA is handled in the
BlockSpec index map (kv head = q head // group), so grouped KV is never
materialized.  Causal and sliding-window masks skip fully-masked kv blocks
via pl.when (no wasted MXU work), and mask partially-covered blocks with
iota comparisons.

VMEM per step: q (Bq, D) + k, v (Bk, D) + scratch (Bq, D + 2) in f32.
Bq = Bk = 128 with D <= 256 stays well under 2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, q_offset: int,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq + q_offset
    kv_start = kj * bk
    # block-level skip tests (static bounds -> traced predicates)
    skip = jnp.bool_(False)
    if causal:
        skip = skip | (kv_start > q_start + bq - 1)
    if window > 0:
        skip = skip | (kv_start + bk - 1 <= q_start - window)

    @pl.when(~skip)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (Bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (Bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (Bq, Bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            s = jnp.where(kpos > qpos, NEG_INF, s)
        if window > 0:
            s = jnp.where(kpos <= qpos - window, NEG_INF, s)
        m_prev = m_ref[...]                                # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with everything masked keep m = -inf; exp(-inf - -inf) guard:
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe)                            # (Bq, Bk)
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        corr = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - m_safe))
        corr = jnp.where(m_prev == NEG_INF, 0.0, corr)     # (Bq, 1)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q, k, v, causal: bool = True, window: int = 0, q_offset: int = 0,
    bq: int = 128, bk: int = 128, interpret: bool = True,
):
    """q (B, H, Sq, D); k, v (B, Hkv, Skv, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    def kv_head(bh):
        return (bh // h) * hkv + (bh % h) // group

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            q_offset=q_offset, bq=bq, bk=bk, nk=nk,
        ),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, kj: (kv_head(bh), kj, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, kj: (kv_head(bh), kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
