"""Pure-jnp oracle: softmax attention with GQA, causal, sliding window."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, causal: bool = True, window: int = 0, q_offset: int = 0):
    """q (B, H, Sq, D); k, v (B, Hkv, Skv, D). H % Hkv == 0.

    window > 0 limits attention to the last `window` kv positions (inclusive
    of self) — Gemma-style local attention.  q_offset shifts query positions
    (chunked prefill / decode with a KV cache).
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.zeros((sq, skv), bool)
    if causal:
        mask = mask | (kpos[None, :] > qpos[:, None])
    if window > 0:
        mask = mask | (kpos[None, :] <= qpos[:, None] - window)
    s = jnp.where(mask[None, None], -jnp.inf, s)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
