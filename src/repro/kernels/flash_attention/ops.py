"""jit'd wrapper: picks the Pallas flash kernel on TPU, the chunked-jnp
path elsewhere (that path is also what the dry-run lowers — see
models/attention.py for the chunked online-softmax implementation)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 128, bk: int = 128,
                    use_pallas: bool = True):
    if not use_pallas:
        return mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=not _on_tpu(),
    )
