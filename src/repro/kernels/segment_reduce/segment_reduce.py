"""Pallas TPU kernel: two-phase sorted-segment sum (message-passing primitive).

GNN aggregation and the partitioner's contraction/connectivity all reduce
values by a sorted key (edges sorted by destination).  A GPU does this with
atomics; the TPU adaptation turns the inner reduction into an MXU matmul:

phase 1 (this kernel, grid over edge blocks):
    local run index r = rank of the row's segment *within the block*
    (0..B-1, computed from sorted-key boundaries), then
        partials = onehot(r).T @ data          -- (B, F) MXU matmul
    plus the run -> global segment id table for the block.

phase 2 (ops.py): scatter-add the (num_blocks * B, F) partials into the
(S, F) output — touches B rows per block instead of every edge, so the
irregular scatter shrinks by the average segment length.

Rows whose seg_id >= num_segments (padding) are zeroed via the one-hot mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(seg_ref, data_ref, partial_ref, segid_ref, *, block_m: int,
            num_segments: int):
    seg = seg_ref[...][:, 0]            # (B,)
    data = data_ref[...]                # (B, F)
    b = block_m
    prev = jnp.concatenate([jnp.full((1,), -1, seg.dtype), seg[:-1]])
    isfirst = seg != prev
    local = jnp.cumsum(isfirst.astype(jnp.int32)) - 1          # (B,) in [0,B)
    valid = seg < num_segments
    onehot = (
        (local[:, None] == jnp.arange(b, dtype=jnp.int32)[None, :])
        & valid[:, None]
    ).astype(data.dtype)                                        # (B, B)
    partial_ref[...] = jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=data.dtype,
    )                                                           # (B, F)
    # run -> global segment id (or num_segments for dead runs)
    segid = jnp.full((b,), num_segments, jnp.int32).at[
        jnp.where(valid & isfirst, local, b - 1)
    ].min(jnp.where(valid & isfirst, seg.astype(jnp.int32), num_segments))
    segid_ref[...] = segid[:, None]


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_m", "interpret")
)
def segment_sum_sorted_pallas(
    data, seg_ids, num_segments: int, block_m: int = 256, interpret: bool = True
):
    m, f = data.shape
    assert m % block_m == 0, (m, block_m)
    nblocks = m // block_m
    seg2 = seg_ids.astype(jnp.int32).reshape(m, 1)
    partials, segids = pl.pallas_call(
        functools.partial(_kernel, block_m=block_m, num_segments=num_segments),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, f), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, f), data.dtype),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(seg2, data)
    # phase 2: combine per-block partials (a straddling segment appears in
    # at most 2 blocks, so this is a short scatter).
    out = jnp.zeros((num_segments + 1, f), data.dtype)
    out = out.at[jnp.clip(segids[:, 0], 0, num_segments)].add(partials)
    return out[:num_segments]
