"""jit'd wrapper for the sorted-segment sum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.ref import segment_sum_sorted_ref
from repro.kernels.segment_reduce.segment_reduce import segment_sum_sorted_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_sum_sorted(data, seg_ids, num_segments: int, block_m: int = 256,
                       use_pallas: bool = True):
    """Sorted-segment sum. data (M, F), seg_ids non-decreasing int32.

    Rows with seg_id >= num_segments are dropped (use as padding).
    """
    if not use_pallas:
        return segment_sum_sorted_ref(data, seg_ids, num_segments)
    m, f = data.shape
    pad = (-m) % block_m
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, pad), constant_values=num_segments)
    return segment_sum_sorted_pallas(
        data, seg_ids, num_segments, block_m=block_m, interpret=not _on_tpu()
    )
