"""Pure-jnp oracle for sorted-segment sum."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_sorted_ref(data, seg_ids, num_segments: int):
    """data (M, F), seg_ids (M,) int32 non-decreasing; rows with seg_id >=
    num_segments are dropped. Returns (num_segments, F)."""
    return jax.ops.segment_sum(data, seg_ids, num_segments=num_segments)
