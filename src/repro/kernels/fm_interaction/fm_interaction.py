"""Pallas TPU kernel: fused FM second-order interaction.

The recsys serving hot path: for each example, reduce its (F, D) field
embeddings to a scalar via the sum-square trick, fused in one VMEM pass
(XLA would otherwise materialize the (B, D) squared-sum intermediates in
HBM between three reductions).

Grid over batch blocks; block shapes (Bb, F, D) chosen so Bb*F*D*4 bytes
fits VMEM (Bb=256, F=39, D=16 -> 640 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(emb_ref, out_ref):
    e = emb_ref[...].astype(jnp.float32)       # (Bb, F, D)
    s = jnp.sum(e, axis=1)                     # (Bb, D)
    sq = jnp.sum(e * e, axis=1)                # (Bb, D)
    out_ref[...] = (0.5 * jnp.sum(s * s - sq, axis=-1, keepdims=True)).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fm_interaction_pallas(emb, block_b: int = 256, interpret: bool = True):
    b, f, d = emb.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    out = pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(emb)
    return out[:, 0]
