"""jit'd wrapper for the FM interaction kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fm_interaction.fm_interaction import fm_interaction_pallas
from repro.kernels.fm_interaction.ref import fm_interaction_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fm_interaction(emb, block_b: int = 256, use_pallas: bool = True):
    """emb (B, F, D) -> (B,) second-order FM scores."""
    if not use_pallas:
        return fm_interaction_ref(emb)
    b = emb.shape[0]
    block_b = min(block_b, b)
    pad = (-b) % block_b
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    out = fm_interaction_pallas(emb, block_b=block_b, interpret=not _on_tpu())
    return out[:b]
