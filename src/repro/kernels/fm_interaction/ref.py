"""Pure-jnp oracle: FM second-order interaction (Rendle ICDM'10).

score(x) = 0.5 * sum_d [ (sum_f e_fd)^2 - sum_f e_fd^2 ]
with e (B, F, D) the per-field embedding vectors (already weighted by the
feature values).  O(F*D) via the sum-square trick vs O(F^2 D) naive.
"""
from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(emb):
    e = emb.astype(jnp.float32)  # accumulate in f32 (the trick cancels badly in bf16)
    s = jnp.sum(e, axis=1)                   # (B, D)
    sq = jnp.sum(e * e, axis=1)              # (B, D)
    return 0.5 * jnp.sum(s * s - sq, axis=-1)  # (B,) float32
