"""Pallas TPU kernel: fused per-vertex part-connectivity reduction.

The Jetlp hot loop (paper Alg 4.2 lines 3-7) computes, for every vertex, its
connectivity to its own part and the best-connected other part.  On the GPU
the paper walks per-vertex hashtables; the TPU adaptation tiles an ELL
(padded-row) adjacency into VMEM and sweeps the k parts with VPU compare+
multiply-accumulate — regular accesses, no atomics, no gather.

Layout per grid step i (rows = vertices):
  nbr_parts (BLOCK_N, D) int32  — neighbor part ids (k on padding slots)
  nwgt      (BLOCK_N, D) int32  — edge weights (0 on padding)
  parts     (BLOCK_N, 1) int32  — own part
Outputs:
  conn_self (BLOCK_N, 1), best_part (BLOCK_N, 1), best_conn (BLOCK_N, 1)

The k-sweep keeps a running (value, part) max using the "smallest part id
wins ties" rule to match the oracle exactly.  VMEM per step:
BLOCK_N * D * 8 bytes + O(BLOCK_N) — BLOCK_N chosen so this is << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_parts_ref, nwgt_ref, parts_ref, conn_self_ref, best_part_ref,
            best_conn_ref, *, k: int):
    nbr_p = nbr_parts_ref[...]          # (B, D) int32
    w = nwgt_ref[...]                   # (B, D) int32
    own = parts_ref[...]                # (B, 1) int32

    def body(p, carry):
        best_c, best_p, conn_self = carry
        conn_p = jnp.sum(jnp.where(nbr_p == p, w, 0), axis=1, keepdims=True)
        is_self = own == p
        conn_self = jnp.where(is_self, conn_p, conn_self)
        # candidate for best-other: strictly better value wins; ties keep
        # the earlier (smaller) part id because we sweep p ascending.
        better = (~is_self) & (conn_p > best_c)
        best_p = jnp.where(better, p, best_p)
        best_c = jnp.where(better, conn_p, best_c)
        return best_c, best_p, conn_self

    zero = jnp.zeros_like(own)
    best_c, best_p, conn_self = jax.lax.fori_loop(
        0, k, body, (zero, jnp.full_like(own, k), zero)
    )
    conn_self_ref[...] = conn_self
    best_part_ref[...] = jnp.where(best_c > 0, best_p, k)
    best_conn_ref[...] = jnp.maximum(best_c, 0)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def jet_gain_pallas(nbr_parts, nwgt, parts, k: int, block_n: int = 256,
                    interpret: bool = True):
    n, d = nbr_parts.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    parts2 = parts.reshape(n, 1)
    out_shape = [
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
    ]
    row_spec = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    col_spec = pl.BlockSpec((block_n, 1), lambda i: (i, 0))
    cs, bp, bc = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[row_spec, row_spec, col_spec],
        out_specs=[col_spec, col_spec, col_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(nbr_parts, nwgt, parts2)
    return cs[:, 0], bp[:, 0], bc[:, 0]
