"""Pure-jnp oracle for the jet_gain kernel.

Inputs (ELL padded adjacency — the TPU-regular layout of CSR, DESIGN.md §2):
  nbr_parts : (N, D) int32 — part id of each neighbor (k for padding slots)
  nwgt      : (N, D) int32 — edge weight (0 for padding slots)
  parts     : (N,)   int32 — current part of each vertex
  k         : static int — number of parts

Outputs (the Jetlp selection quantities, paper Alg 4.2 lines 3-7):
  conn_self : (N,) conn(v, P_s(v))
  best_part : (N,) argmax_{p != P_s(v)} conn(v, p); k if none
  best_conn : (N,) its connectivity (0 if none)
"""
from __future__ import annotations

import jax.numpy as jnp


def jet_gain_ref(nbr_parts, nwgt, parts, k: int):
    n, d = nbr_parts.shape
    cols = jnp.arange(k + 1, dtype=jnp.int32)
    # (N, D, k+1) one-hot accumulate -> (N, k+1); memory fine for oracle use
    onehot = (nbr_parts[:, :, None] == cols[None, None, :]).astype(jnp.int32)
    mat = jnp.sum(onehot * nwgt[:, :, None], axis=1)
    rows = jnp.arange(n)
    conn_self = mat[rows, parts]
    masked = jnp.where(
        (cols[None, :] == parts[:, None]) | (cols[None, :] == k), -1, mat
    )
    best_part = jnp.argmax(masked, axis=1).astype(jnp.int32)
    best_conn = jnp.max(masked, axis=1)
    none = best_conn <= 0
    return (
        conn_self.astype(jnp.int32),
        jnp.where(none, k, best_part).astype(jnp.int32),
        jnp.where(none, 0, best_conn).astype(jnp.int32),
    )
