"""jit'd public wrapper for the jet_gain kernel.

Chooses the Pallas kernel (interpret=True on CPU, compiled on TPU) and
provides the CSR->ELL conversion used by the refinement layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.jet_gain.jet_gain import jet_gain_pallas
from repro.kernels.jet_gain.ref import jet_gain_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def csr_to_ell(g, max_degree: int | None = None):
    """Pad CSR adjacency to (N, D). Returns (nbr (N,D), wgt (N,D)).

    Slots beyond a vertex's degree have nbr == N (ghost) and weight 0.
    """
    deg = jnp.asarray(g.degrees())
    d = int(max_degree) if max_degree else int(jnp.max(deg))
    n = g.n_max
    slots = jnp.arange(d, dtype=jnp.int32)
    eidx = g.xadj[:-1, None] + slots[None, :]
    valid = slots[None, :] < deg[:, None]
    eidx = jnp.clip(eidx, 0, g.m_max - 1)
    nbr = jnp.where(valid, g.adjncy[eidx], n)
    wgt = jnp.where(valid, g.adjwgt[eidx], 0)
    return nbr, wgt


def jet_gain(nbr, wgt, parts, k: int, block_n: int = 256, use_pallas=None):
    """Fused conn_self / best_part / best_conn (see jet_gain.py).

    ``nbr`` holds neighbor ids; part ids are looked up here (outside the
    kernel — TPU kernels avoid arbitrary gathers) and the padded ghost id N
    maps to ghost part k.
    """
    n, d = nbr.shape
    parts_ext = jnp.concatenate([parts, jnp.array([k], jnp.int32)])
    nbr_parts = parts_ext[jnp.clip(nbr, 0, parts.shape[0])].astype(jnp.int32)
    nbr_parts = jnp.where(nbr >= parts.shape[0], k, nbr_parts)
    if use_pallas is None:
        use_pallas = True
    if not use_pallas:
        return jet_gain_ref(nbr_parts, wgt, parts, k)
    pad = (-n) % block_n
    if pad:
        nbr_parts = jnp.pad(nbr_parts, ((0, pad), (0, 0)), constant_values=k)
        wgt = jnp.pad(wgt, ((0, pad), (0, 0)))
        parts = jnp.pad(parts, (0, pad), constant_values=k)
    cs, bp, bc = jet_gain_pallas(
        nbr_parts, wgt, parts, k, block_n=block_n, interpret=not _on_tpu()
    )
    return cs[:n], bp[:n], bc[:n]
