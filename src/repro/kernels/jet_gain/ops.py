"""jit'd public wrapper for the jet_gain kernel.

Chooses the Pallas kernel (interpret=True on CPU, compiled on TPU) and
provides the CSR->ELL conversion used by the refinement layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.jet_gain.jet_gain import jet_gain_pallas
from repro.kernels.jet_gain.ref import jet_gain_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def csr_to_ell(g, max_degree: int | None = None):
    """Pad CSR adjacency to (N, D). Returns (nbr (N,D), wgt (N,D)).

    Slots beyond a vertex's degree have nbr == N (ghost) and weight 0.
    """
    deg = jnp.asarray(g.degrees())
    d = int(max_degree) if max_degree else int(jnp.max(deg))
    n = g.n_max
    slots = jnp.arange(d, dtype=jnp.int32)
    eidx = g.xadj[:-1, None] + slots[None, :]
    valid = slots[None, :] < deg[:, None]
    eidx = jnp.clip(eidx, 0, g.m_max - 1)
    nbr = jnp.where(valid, g.adjncy[eidx], n)
    wgt = jnp.where(valid, g.adjwgt[eidx], 0)
    return nbr, wgt


def lookup_nbr_parts(nbr, parts, k: int):
    """(N, D) neighbor part ids from a parts vector; ghost slots map to k."""
    parts_ext = jnp.concatenate([parts, jnp.array([k], jnp.int32)])
    nbr_parts = parts_ext[jnp.clip(nbr, 0, parts.shape[0])].astype(jnp.int32)
    return jnp.where(nbr >= parts.shape[0], k, nbr_parts)


def update_nbr_parts(nbr, nbr_parts, move, dest, k: int):
    """Incrementally rewrite slots whose neighbor moved (paper Alg 4.4).

    Elementwise over the (N, D) ELL tile — no gather of the full parts
    vector, so the maintained state is the only connectivity read.
    """
    move_ext = jnp.concatenate([move, jnp.zeros((1,), bool)])
    dest_ext = jnp.concatenate(
        [dest.astype(jnp.int32), jnp.array([k], jnp.int32)]
    )
    idx = jnp.clip(nbr, 0, move.shape[0])
    return jnp.where(move_ext[idx], dest_ext[idx], nbr_parts)


def ell_to_matrix(nbr_parts, wgt, k: int):
    """(N, k+1) dense connectivity matrix from maintained ELL state.

    Used by the (rare) rebalance iterations, which need valid-destination
    queries the fused kernel does not answer.
    """
    n, d = nbr_parts.shape
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, d))
    mat = jnp.zeros((n, k + 1), jnp.int32)
    return mat.at[rows, nbr_parts].add(wgt)


def jet_gain_from_parts(nbr_parts, wgt, parts, k: int, block_n: int = 256,
                        use_pallas=None):
    """Fused conn_self / best_part / best_conn from precomputed neighbor
    parts — the entry point for the stateful ELL backend.

    ``use_pallas=None`` auto-selects: the compiled kernel on TPU, the
    bit-identical pure-jnp k-sweep elsewhere (interpret-mode Pallas is for
    kernel validation, not production CPU runs).
    """
    n, d = nbr_parts.shape
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return jet_gain_ref(nbr_parts, wgt, parts, k)
    pad = (-n) % block_n
    if pad:
        nbr_parts = jnp.pad(nbr_parts, ((0, pad), (0, 0)), constant_values=k)
        wgt = jnp.pad(wgt, ((0, pad), (0, 0)))
        parts = jnp.pad(parts, (0, pad), constant_values=k)
    cs, bp, bc = jet_gain_pallas(
        nbr_parts, wgt, parts, k, block_n=block_n, interpret=not _on_tpu()
    )
    return cs[:n], bp[:n], bc[:n]


def jet_gain(nbr, wgt, parts, k: int, block_n: int = 256, use_pallas=None):
    """Fused conn_self / best_part / best_conn (see jet_gain.py).

    ``nbr`` holds neighbor ids; part ids are looked up here (outside the
    kernel — TPU kernels avoid arbitrary gathers) and the padded ghost id N
    maps to ghost part k.
    """
    nbr_parts = lookup_nbr_parts(nbr, parts, k)
    return jet_gain_from_parts(nbr_parts, wgt, parts, k, block_n=block_n,
                               use_pallas=use_pallas)
