"""The Jet partitioner — multilevel driver (Alg 2.1) with batched trials.

coarsen -> initial partition (coarsest) -> [project -> Jet refine] per level.
Host drives the level loop (shapes change per level); everything inside a
level is jitted.

Trial batching (DESIGN.md §9): the uncoarsening half runs vmapped over T
independent seed trials on ONE shared hierarchy.  :func:`uncoarsen_level`
fuses project -> mask -> ConnState build -> Jet refinement into a single
jitted program keyed on the shape-schedule rung, so kernels compile once
per rung regardless of T; the best trial (balanced first, then lowest cut —
the same ordering as Alg 4.1's best tracking) is selected on device and
only materialized at the finest level.  The uncoarsening phase performs
exactly ONE blocking host transfer, after the level loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coarsen as co
from repro.core import connectivity as cn
from repro.core import graph as gr
from repro.core import initial, metrics, refine


@dataclass
class PartitionConfig:
    k: int = 8
    lam: float = 0.03                 # balance slack (paper: 1-10%)
    phi: float = 0.999                # quality/runtime tolerance (paper §4)
    c_finest: float = 0.25            # Eq 4.3 ratio, finest level
    c_coarse: float = 0.75            # Eq 4.3 ratio, other levels
    coarse_target: int = 4096         # paper coarsens to 4-8k vertices
    max_levels: int = 40              # coarsening depth cap
    stall_ratio: float = 0.95         # terminate when a level shrinks less
    coarsen_mode: str = "device"      # device (jitted levels) | host (legacy
                                      # numpy repack) — see DESIGN.md §8
    bucket_ratio: float = 1.6         # shape-schedule geometric shrink
    bucket_safety: float = 1.25       # headroom multiplier on the shrink
    bucket_align: int = 64            # capacity rung alignment
    patience: int = 12                # iterations without a new best
    max_iter: int = 300
    b_max: int = 2                    # weak rebalances before strong
    backend: str = "dense"            # connectivity backend: dense|sorted|ell
    rebuild_every: int = 0            # full ConnState rebuild period (0=never,
                                      # 1=paper's always-rebuild fallback)
    init_method: str = "voronoi"      # random|voronoi
    variant: str = "full"             # Jetlp variant (Table 3 ablations)
    seed: int = 0
    trials: int = 1                   # best-of-N trials, vmapped over one
                                      # shared hierarchy (DESIGN.md §9)
    trial_seeds: tuple | None = None  # per-trial init seeds; default
                                      # (seed, seed+1, ..., seed+trials-1)


@dataclass
class PartitionResult:
    parts: jnp.ndarray
    cut: int
    imbalance: float
    balanced: bool
    levels: int
    times: dict = field(default_factory=dict)
    level_stats: list = field(default_factory=list)
    config: Any = None
    trials: int = 1
    best_trial: int = 0               # index into the trial batch
    trial_cuts: list = field(default_factory=list)      # per-trial best cut
    trial_balanced: list = field(default_factory=list)  # per-trial balance
    trial_parts: Any = None           # (T, n_max) finest-level parts batch


def _resolve_trial_seeds(cfg: PartitionConfig) -> tuple:
    if cfg.trials < 1:
        raise ValueError(f"trials must be >= 1, got {cfg.trials}")
    if cfg.trial_seeds is None:
        return tuple(cfg.seed + t for t in range(cfg.trials))
    seeds = tuple(int(s) for s in cfg.trial_seeds)
    if len(seeds) != cfg.trials:
        raise ValueError(
            f"trial_seeds has {len(seeds)} entries but trials={cfg.trials}"
        )
    return seeds


def _uncoarsen_trials(
    fine, cmap, parts_batch, phi, active, *,
    k, lam, c, backend, patience, max_iter, b_max, variant, rebuild_every,
    max_degree,
):
    """project -> ghost-mask -> build_state -> Alg 4.1 loop, vmapped over T.

    The shared body of :func:`uncoarsen_level` (trial batching) and
    :func:`uncoarsen_level_fleet` (graph × trial batching).  ``active`` is
    None on the single-graph path; on the fleet path it is the lane's
    refine-active flag, threaded into the loop condition so frozen lanes
    pass their (identity-projected) partition through untouched.
    """

    def one_trial(parts_coarse):
        parts = co.project_partition(cmap, parts_coarse)
        parts = jnp.where(fine.vertex_mask(), parts, k).astype(jnp.int32)
        conn0 = cn.build_state(fine, parts, k, backend, max_degree=max_degree)
        return refine._refine_loop(
            fine, parts, conn0, phi,
            k=k, lam=lam, c=c, backend=backend, patience=patience,
            max_iter=max_iter, b_max=b_max, variant=variant,
            rebuild_every=rebuild_every, active=active,
        )

    return jax.vmap(one_trial)(parts_batch)


@partial(
    jax.jit,
    static_argnames=(
        "k", "lam", "c", "backend", "patience", "max_iter", "b_max",
        "variant", "rebuild_every", "max_degree",
    ),
)
def uncoarsen_level(
    fine,
    cmap: jnp.ndarray,
    parts_batch: jnp.ndarray,
    phi,
    *,
    k: int,
    lam: float,
    c: float,
    backend: str,
    patience: int,
    max_iter: int,
    b_max: int,
    variant: str,
    rebuild_every: int,
    max_degree: int | None = None,
):
    """One uncoarsening level, fused and vmapped over the trial axis.

    project -> ghost-mask -> ConnState build -> Jet refinement loop as a
    single XLA program.  ``parts_batch`` is (T, nc_max) coarse parts (pass
    the identity cmap at the coarsest level); returns the refined (T,
    n_max) batch plus per-trial stats arrays, all shape (T,).

    Compilation is keyed on the capacity rung — (fine.n_max, fine.m_max,
    nc_max, T) plus the static knobs — so re-running on a same-bucket level
    hits the cache.  Static per-trial arrays (the graph, the ELL adjacency)
    stay unbatched inside the vmap: only genuinely per-trial state carries
    a T axis (see DESIGN.md §9 for the ConnState batch-polymorphism rules).
    """
    return _uncoarsen_trials(
        fine, cmap, parts_batch, phi, None,
        k=k, lam=lam, c=c, backend=backend, patience=patience,
        max_iter=max_iter, b_max=b_max, variant=variant,
        rebuild_every=rebuild_every, max_degree=max_degree,
    )


@partial(
    jax.jit,
    static_argnames=(
        "k", "lam", "c", "backend", "patience", "max_iter", "b_max",
        "variant", "rebuild_every", "max_degree",
    ),
)
def uncoarsen_level_fleet(
    fine,
    cmap: jnp.ndarray,
    parts_batch: jnp.ndarray,
    active: jnp.ndarray,
    phi,
    *,
    k: int,
    lam: float,
    c: float,
    backend: str,
    patience: int,
    max_iter: int,
    b_max: int,
    variant: str,
    rebuild_every: int,
    max_degree: int | None = None,
):
    """One uncoarsening level vmapped over graphs × trials (DESIGN.md §10).

    ``fine`` is a stacked (B, ...) graph at this level's shared bucket
    capacity, ``cmap`` (B, n_max), ``parts_batch`` (B, T, nc_max), and
    ``active`` (B,) bool — the per-lane refine flag from the batched
    coarsening driver.  Inactive lanes (their own hierarchy is shallower
    than the bucket's) project through their identity cmap and skip the
    refinement loop entirely: their loop condition is false at iteration 0,
    so the carry freezes and the partition passes through bit-untouched.

    Compilation is keyed on (B, T, rung shapes) plus the static knobs —
    one executable per (rung, k) signature serves all B lanes and T trials.
    """

    def one_graph(g, cm, pb, act):
        return _uncoarsen_trials(
            g, cm, pb, phi, act,
            k=k, lam=lam, c=c, backend=backend, patience=patience,
            max_iter=max_iter, b_max=b_max, variant=variant,
            rebuild_every=rebuild_every, max_degree=max_degree,
        )

    return jax.vmap(one_graph)(fine, cmap, parts_batch, active)


def _best_trial(balanced: jnp.ndarray, cut: jnp.ndarray,
                maxsize: jnp.ndarray) -> jnp.ndarray:
    """Device-side best-of-T selection (same ordering as Alg 4.1's best
    tracking): a balanced trial always beats an unbalanced one; among
    balanced trials the lowest cut wins; if no trial balanced, the lowest
    max part weight wins with the lower cut breaking ties.  ``argmin``
    takes the first index on remaining ties, so selection is deterministic.
    """
    INF = jnp.int32(0x7FFFFFFF)
    idx_bal = jnp.argmin(jnp.where(balanced, cut, INF)).astype(jnp.int32)
    m0 = jnp.min(maxsize)
    idx_imb = jnp.argmin(jnp.where(maxsize == m0, cut, INF)).astype(jnp.int32)
    return jnp.where(jnp.any(balanced), idx_bal, idx_imb)


@partial(jax.jit, static_argnames=("k", "lam"))
def _fleet_epilogue(gb, parts_bt, best_balanced, best_cost, best_maxsize,
                    *, k: int, lam: float):
    """Per-lane best-trial selection + final metrics, all on device."""

    def one(g, parts_t, bb, bc, bm):
        idx = _best_trial(bb, bc, bm)
        parts = parts_t[idx]
        sizes = metrics.part_sizes(g, parts, k)
        W = g.total_vweight()
        return {
            "best_idx": idx,
            "parts": parts,
            "cut": metrics.cutsize(g, parts),
            "imbalance": metrics.imbalance(sizes, W, k),
            "balanced": metrics.is_balanced(sizes, W, k, lam),
        }

    return jax.vmap(one)(gb, parts_bt, best_balanced, best_cost, best_maxsize)


@dataclass
class FleetBucket:
    """Host-side record of one shape bucket's run (for reports and the
    executable-count accounting in ``bench_partitioner.fleet_ab``)."""

    capacity: tuple          # (n_cap, m_cap) rung-0 capacity of the bucket
    indices: list            # fleet indices of the member graphs
    levels: int              # batched hierarchy depth (levels list length)
    level_stats: list = field(default_factory=list)  # coarsest-first metas


@dataclass
class FleetResult:
    """``partition_fleet`` output: per-graph results in input order plus
    the bucket/schedule accounting."""

    results: list            # list[PartitionResult], input order
    buckets: list            # list[FleetBucket]
    times: dict = field(default_factory=dict)
    trials: int = 1
    config: Any = None


def partition_fleet_stacked(
    buckets, cfg: PartitionConfig, schedule, times_extra=None,
) -> FleetResult:
    """Partition pre-stacked shape buckets — the serving entry point.

    ``buckets`` is a list of :class:`~repro.core.graph.StackedBucket`
    (e.g. from a :class:`~repro.core.graph.BucketAssembler` flush) and
    ``schedule`` the fixed §8 capacity ladder they were assembled on.
    Runs the same batched V-cycle as :func:`partition_fleet` but skips
    admission entirely — bucket assignment, re-padding, and stacking
    already happened, possibly incrementally as requests arrived.

    Returns a :class:`FleetResult` whose ``results`` is a ``{tag:
    PartitionResult}`` dict keyed by the buckets' lane tags; filler lanes
    (tag ``None``) are computed (they pin the batch width so compiled
    signatures stay stable) but dropped from ``results``.
    """
    if not buckets:
        raise ValueError("partition_fleet_stacked needs at least one bucket")
    k = cfg.k
    seeds = _resolve_trial_seeds(cfg)
    trials = cfg.trials
    times = {"coarsen_s": 0.0, "initpart_s": 0.0, "uncoarsen_s": 0.0,
             "fetch_s": 0.0}
    if times_extra:  # e.g. the wrapper's admission/bucketing time, so
        times.update(times_extra)  # member times keep the full accounting

    pending = []  # (bucket record, metas, fetch pytree, device parts_bt)
    for sb in buckets:
        cap = sb.capacity
        idxs = list(sb.tags)
        B = len(idxs)
        gb = sb.graph

        t0 = time.perf_counter()
        levels = co.multilevel_coarsen_fleet(
            gb, schedule,
            coarse_target=cfg.coarse_target, max_levels=cfg.max_levels,
            stall_ratio=cfg.stall_ratio, seed=cfg.seed,
        )
        times["coarsen_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        parts_bt = initial.initial_partition_fleet(
            levels[-1].graph, k, seeds, method=cfg.init_method
        )
        times["initpart_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        stats_per_level = []
        metas = []
        for i in range(len(levels) - 1, -1, -1):
            lv = levels[i]
            gi = lv.graph
            c = cfg.c_finest if i == 0 else cfg.c_coarse
            # static ELL width: max over lanes, from the coarsening stats —
            # frozen lanes are included (their build_state runs too)
            max_deg = (
                int(lv.stats["max_degree"].max()) if cfg.backend == "ell"
                else None
            )
            n_cap_i = gi.vwgt.shape[1]
            if i == len(levels) - 1:
                cmap = jnp.broadcast_to(
                    jnp.arange(n_cap_i, dtype=jnp.int32), (B, n_cap_i)
                )
            else:
                cmap = lv.cmap
            parts_bt, stats = uncoarsen_level_fleet(
                gi, cmap, parts_bt, jnp.asarray(lv.active), cfg.phi,
                k=k, lam=cfg.lam, c=c, backend=cfg.backend,
                patience=cfg.patience, max_iter=cfg.max_iter,
                b_max=cfg.b_max, variant=cfg.variant,
                rebuild_every=cfg.rebuild_every, max_degree=max_deg,
            )
            stats_per_level.append(stats)
            meta = {
                "level": i,
                "n_max": lv.stats["n_max"], "m_max": lv.stats["m_max"],
                "n": lv.stats["n"], "m": lv.stats["m"],
                "max_degree": lv.stats["max_degree"],
                "active": lv.active,
            }
            if max_deg is not None:
                meta["ell_width"] = max_deg
            metas.append(meta)

        fstats = stats_per_level[-1]
        ep = _fleet_epilogue(
            levels[0].graph, parts_bt,
            fstats["best_balanced"], fstats["best_cost"],
            fstats["best_maxsize"], k=k, lam=cfg.lam,
        )
        fetch = {
            "stats": {
                kk: jnp.stack([s[kk] for s in stats_per_level])  # (L, B, T)
                for kk in stats_per_level[0]
            },
            **ep,
            "trial_cuts": fstats["best_cost"],        # (B, T)
            "trial_balanced": fstats["best_balanced"],
        }
        bucket = FleetBucket(capacity=cap, indices=idxs, levels=len(levels),
                             level_stats=metas)
        pending.append((bucket, sb.orig_n_max, metas, fetch, parts_bt))
        times["uncoarsen_s"] += time.perf_counter() - t0

    # the ONE blocking transfer of the whole fleet's uncoarsening phase
    t0 = time.perf_counter()
    host_all = jax.device_get([p[3] for p in pending])
    times["fetch_s"] = time.perf_counter() - t0
    times["total_s"] = sum(times.values())

    results: dict = {}
    out_buckets = []
    for (bucket, orig_n_max, metas, _, parts_bt), host in \
            zip(pending, host_all):
        out_buckets.append(bucket)
        cap_n = bucket.capacity[0]
        for j, tag in enumerate(bucket.indices):
            if tag is None:  # filler lane: batch-width ballast only
                continue
            own_n_max = orig_n_max[j]
            p = np.asarray(host["parts"][j])
            # parts AND trial_parts line up with the caller's own padding
            # (standalone contract: trial row t has the same shape as parts)
            tp = parts_bt[j]
            if own_n_max <= cap_n:
                p = p[:own_n_max]
                tp = tp[:, :own_n_max]
            else:
                p = np.concatenate(
                    [p, np.full(own_n_max - cap_n, k, p.dtype)]
                )
                tp = jnp.pad(tp, ((0, 0), (0, own_n_max - cap_n)),
                             constant_values=k)
            level_stats = []
            for li, meta in enumerate(metas):
                per = {kk: host["stats"][kk][li, j]
                       for kk in host["stats"]}
                entry = {
                    "level": meta["level"],
                    "n": int(meta["n"][j]), "m": int(meta["m"][j]),
                    "max_degree": int(meta["max_degree"][j]),
                    "n_max": meta["n_max"], "m_max": meta["m_max"],
                    "active": bool(meta["active"][j]),
                }
                if trials == 1:
                    entry |= {kk: int(vv[0]) for kk, vv in per.items()}
                else:
                    entry |= {kk: [int(x) for x in vv]
                              for kk, vv in per.items()}
                level_stats.append(entry)
            results[tag] = PartitionResult(
                parts=jnp.asarray(p),
                cut=int(host["cut"][j]),
                imbalance=float(host["imbalance"][j]),
                balanced=bool(host["balanced"][j]),
                levels=int(sum(m["active"][j] for m in metas)),
                # phase times are fleet-wide aggregates (one program serves
                # every member) — flagged so readers never attribute the
                # whole fleet's cost to a single graph
                times=dict(times, shared_across_fleet=True),
                level_stats=level_stats,
                config=cfg,
                trials=trials,
                best_trial=int(host["best_idx"][j]),
                trial_cuts=[int(x) for x in host["trial_cuts"][j]],
                trial_balanced=[bool(x) for x in host["trial_balanced"][j]],
                trial_parts=tp,
            )
    return FleetResult(results=results, buckets=out_buckets, times=times,
                       trials=trials, config=cfg)


def partition_fleet(graphs, cfg: PartitionConfig,
                    schedule=None) -> FleetResult:
    """Partition a fleet of graphs as shape-bucketed batched V-cycles.

    Graphs are grouped into static shape buckets on one shared §8 capacity
    ladder (`graph.bucket_graphs`); each bucket's members are stacked along
    a leading batch axis and run through coarsening, initial partitioning,
    and uncoarsening vmapped over B graphs × T trials — one jitted
    executable per (rung, k) signature serves the whole bucket.  Per-graph
    termination (coarsening depth, stalls) is select-masked per lane, so
    every graph's cut and parts vector is bit-identical to its standalone
    ``partition()`` run (tests/test_fleet.py).

    With ``schedule`` given, bucketing runs on that fixed ladder instead
    of one derived from the fleet max — the serving path, where rung
    stability across calls keeps compiled executables warm (§11).

    Host syncs: one batched (n, m) fetch at admission, one (B, 3) stat
    fetch per coarsening level per bucket (same cadence as standalone), and
    exactly ONE blocking transfer for all uncoarsening results of the whole
    fleet, after every bucket's level loop has been dispatched.
    """
    graphs = list(graphs)
    if not graphs:
        raise ValueError("partition_fleet needs at least one graph")
    t0 = time.perf_counter()
    schedule, bucket_map = gr.bucket_graphs(
        graphs, ratio=cfg.bucket_ratio, safety=cfg.bucket_safety,
        stall_ratio=cfg.stall_ratio, align=cfg.bucket_align,
        schedule=schedule,
    )
    buckets = []
    for cap in sorted(bucket_map, reverse=True):
        idxs = bucket_map[cap]
        members = [
            g if (g.n_max, g.m_max) == cap else g.with_capacity(*cap)
            for g in (graphs[i] for i in idxs)
        ]
        buckets.append(gr.StackedBucket(
            capacity=cap,
            graph=gr.stack_graphs(members),
            tags=tuple(idxs),
            orig_n_max=tuple(graphs[i].n_max for i in idxs),
        ))
    bucket_s = time.perf_counter() - t0

    sres = partition_fleet_stacked(buckets, cfg, schedule,
                                   times_extra={"bucket_s": bucket_s})
    results: list = [None] * len(graphs)
    for tag, r in sres.results.items():
        results[tag] = r
    return FleetResult(results=results, buckets=sres.buckets,
                       times=sres.times, trials=sres.trials, config=cfg)


def partition(g, cfg: PartitionConfig) -> PartitionResult:
    """Full multilevel partition of ``g`` into ``cfg.k`` parts.

    With ``cfg.trials = T > 1``, the whole uncoarsening phase runs vmapped
    over T seed trials on the shared hierarchy and the returned partition
    is the device-selected best trial; ``trial_cuts`` / ``trial_balanced``
    / ``trial_parts`` expose the full batch.  Trial ``t`` is bit-identical
    to a ``trials=1`` run with ``trial_seeds=(seeds[t],)``.
    """
    k = cfg.k
    seeds = _resolve_trial_seeds(cfg)
    trials = cfg.trials
    t0 = time.perf_counter()
    levels = co.multilevel_coarsen(
        g,
        coarse_target=cfg.coarse_target,
        max_levels=cfg.max_levels,
        stall_ratio=cfg.stall_ratio,
        seed=cfg.seed,
        mode=cfg.coarsen_mode,
        bucket_ratio=cfg.bucket_ratio,
        bucket_safety=cfg.bucket_safety,
        bucket_align=cfg.bucket_align,
    )
    t_coarsen = time.perf_counter() - t0

    t0 = time.perf_counter()
    gc = levels[-1].graph
    parts_b = initial.initial_partition_batch(gc, k, seeds,
                                              method=cfg.init_method)
    t_init = time.perf_counter() - t0

    t0 = time.perf_counter()
    # refine coarsest, then uncoarsen.  Each level is ONE jitted
    # `uncoarsen_level` call (project -> mask -> ConnState build -> Alg 4.1
    # loop) vmapped over the trial axis; per-trial stats stay on device and
    # are fetched in a single transfer after the loop.
    stats_per_level = []   # dicts of (T,) traced stat arrays, coarsest first
    meta_per_level = []    # host-side size stats captured during coarsening
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        lv_stats = levels[i].stats
        c = cfg.c_finest if i == 0 else cfg.c_coarse
        if cfg.backend == "ell":
            # static max degree from the stats captured during coarsening —
            # no extra device->host sync per level
            max_deg = (
                lv_stats["max_degree"] if lv_stats is not None
                else int(np.max(np.asarray(gi.degrees())))
            )
        else:
            max_deg = None
        if i == len(levels) - 1:
            # coarsest level: no projection — the identity cmap keeps the
            # call signature (and therefore the compiled executable) shared
            cmap = jnp.arange(gi.n_max, dtype=jnp.int32)
        else:
            cmap = levels[i].cmap
        parts_b, stats = uncoarsen_level(
            gi, cmap, parts_b, cfg.phi,
            k=k, lam=cfg.lam, c=c, backend=cfg.backend,
            patience=cfg.patience, max_iter=cfg.max_iter, b_max=cfg.b_max,
            variant=cfg.variant, rebuild_every=cfg.rebuild_every,
            max_degree=max_deg,
        )
        stats_per_level.append(stats)
        meta = (
            {kk: lv_stats[kk] for kk in ("n", "m", "n_max", "m_max",
                                         "max_degree")}
            if lv_stats is not None
            else {"n": int(gi.n), "m": int(gi.m),
                  "n_max": gi.n_max, "m_max": gi.m_max}
        )
        if max_deg is not None:
            meta["max_degree"] = max_deg
        meta_per_level.append({"level": i} | meta)

    # shape_schedule rung 0 is the caller's exact capacity, so the finest
    # parts batch always lines up with g's padding
    assert parts_b.shape[1] == g.n_max, (parts_b.shape, g.n_max)

    # device epilogue: best-trial selection + final metrics, then the ONE
    # blocking transfer of the whole uncoarsening phase
    fstats = stats_per_level[-1]
    best_idx = _best_trial(
        fstats["best_balanced"], fstats["best_cost"], fstats["best_maxsize"]
    )
    parts = parts_b[best_idx]
    sizes = metrics.part_sizes(g, parts, k)
    W = g.total_vweight()
    fetch = {
        "stats": {
            kk: jnp.stack([s[kk] for s in stats_per_level])  # (L, T)
            for kk in stats_per_level[0]
        },
        "best_idx": best_idx,
        "cut": metrics.cutsize(g, parts),
        "imbalance": metrics.imbalance(sizes, W, k),
        "balanced": metrics.is_balanced(sizes, W, k, cfg.lam),
        "trial_cuts": fstats["best_cost"],
        "trial_balanced": fstats["best_balanced"],
    }
    host = jax.device_get(fetch)
    t_uncoarsen = time.perf_counter() - t0

    level_stats = []
    for j, meta in enumerate(meta_per_level):
        per = {kk: host["stats"][kk][j] for kk in host["stats"]}
        if trials == 1:
            level_stats.append(meta | {kk: int(vv[0]) for kk, vv in per.items()})
        else:
            level_stats.append(
                meta | {kk: [int(x) for x in vv] for kk, vv in per.items()}
            )

    return PartitionResult(
        parts=parts,
        cut=int(host["cut"]),
        imbalance=float(host["imbalance"]),
        balanced=bool(host["balanced"]),
        levels=len(levels),
        times={
            "coarsen_s": t_coarsen,
            "initpart_s": t_init,
            "uncoarsen_s": t_uncoarsen,
            "total_s": t_coarsen + t_init + t_uncoarsen,
        },
        level_stats=level_stats,
        config=cfg,
        trials=trials,
        best_trial=int(host["best_idx"]),
        trial_cuts=[int(x) for x in host["trial_cuts"]],
        trial_balanced=[bool(x) for x in host["trial_balanced"]],
        trial_parts=parts_b,
    )


def refine_only(g, parts0, cfg: PartitionConfig) -> PartitionResult:
    """Refinement-effectiveness mode: refine an imported partition on the
    finest graph only (paper §5.1 effectiveness tests)."""
    if cfg.backend == "ell":
        # static ELL width resolved ONCE, up front — not mid-call inside
        # jet_refine, which would block the device queue between the parts
        # normalization and the loop launch
        max_deg = int(np.max(np.asarray(g.degrees())))
    else:
        max_deg = None
    parts, stats = refine.jet_refine(
        g,
        jnp.asarray(np.asarray(parts0), dtype=jnp.int32),
        cfg.k,
        lam=cfg.lam,
        c=cfg.c_finest,
        phi=cfg.phi,
        backend=cfg.backend,
        patience=cfg.patience,
        max_iter=cfg.max_iter,
        b_max=cfg.b_max,
        variant=cfg.variant,
        rebuild_every=cfg.rebuild_every,
        max_degree=max_deg,
    )
    sizes = metrics.part_sizes(g, parts, cfg.k)
    W = g.total_vweight()
    return PartitionResult(
        parts=parts,
        cut=int(metrics.cutsize(g, parts)),
        imbalance=float(metrics.imbalance(sizes, W, cfg.k)),
        balanced=bool(metrics.is_balanced(sizes, W, cfg.k, cfg.lam)),
        levels=1,
        level_stats=[{kk: int(vv) for kk, vv in stats.items()}],
        config=cfg,
    )
