"""The Jet partitioner — multilevel driver (Alg 2.1).

coarsen -> initial partition (coarsest) -> [project -> Jet refine] per level.
Host drives the level loop (shapes change per level); everything inside a
level is jitted.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import coarsen as co
from repro.core import connectivity as cn
from repro.core import initial, metrics, refine


@dataclass
class PartitionConfig:
    k: int = 8
    lam: float = 0.03                 # balance slack (paper: 1-10%)
    phi: float = 0.999                # quality/runtime tolerance (paper §4)
    c_finest: float = 0.25            # Eq 4.3 ratio, finest level
    c_coarse: float = 0.75            # Eq 4.3 ratio, other levels
    coarse_target: int = 4096         # paper coarsens to 4-8k vertices
    max_levels: int = 40              # coarsening depth cap
    stall_ratio: float = 0.95         # terminate when a level shrinks less
    coarsen_mode: str = "device"      # device (jitted levels) | host (legacy
                                      # numpy repack) — see DESIGN.md §8
    bucket_ratio: float = 1.6         # shape-schedule geometric shrink
    bucket_safety: float = 1.25       # headroom multiplier on the shrink
    bucket_align: int = 64            # capacity rung alignment
    patience: int = 12                # iterations without a new best
    max_iter: int = 300
    b_max: int = 2                    # weak rebalances before strong
    backend: str = "dense"            # connectivity backend: dense|sorted|ell
    rebuild_every: int = 0            # full ConnState rebuild period (0=never,
                                      # 1=paper's always-rebuild fallback)
    init_method: str = "voronoi"      # random|voronoi
    variant: str = "full"             # Jetlp variant (Table 3 ablations)
    seed: int = 0


@dataclass
class PartitionResult:
    parts: jnp.ndarray
    cut: int
    imbalance: float
    balanced: bool
    levels: int
    times: dict = field(default_factory=dict)
    level_stats: list = field(default_factory=list)
    config: Any = None


def partition(g, cfg: PartitionConfig) -> PartitionResult:
    """Full multilevel partition of ``g`` into ``cfg.k`` parts."""
    k = cfg.k
    t0 = time.perf_counter()
    levels = co.multilevel_coarsen(
        g,
        coarse_target=cfg.coarse_target,
        max_levels=cfg.max_levels,
        stall_ratio=cfg.stall_ratio,
        seed=cfg.seed,
        mode=cfg.coarsen_mode,
        bucket_ratio=cfg.bucket_ratio,
        bucket_safety=cfg.bucket_safety,
        bucket_align=cfg.bucket_align,
    )
    t_coarsen = time.perf_counter() - t0

    t0 = time.perf_counter()
    gc = levels[-1].graph
    parts = initial.initial_partition(gc, k, seed=cfg.seed, method=cfg.init_method)
    t_init = time.perf_counter() - t0

    t0 = time.perf_counter()
    level_stats = []
    # refine coarsest, then uncoarsen.  The driver owns the per-level
    # ConnState: built once here, threaded through the whole refinement
    # loop, and advanced incrementally after every move list (Alg 4.4).
    for i in range(len(levels) - 1, -1, -1):
        gi = levels[i].graph
        lv_stats = levels[i].stats
        c = cfg.c_finest if i == 0 else cfg.c_coarse
        parts = jnp.where(gi.vertex_mask(), parts, k).astype(jnp.int32)
        if cfg.backend == "ell":
            # static max degree from the stats captured during coarsening —
            # no extra device->host sync per level
            max_deg = (
                lv_stats["max_degree"] if lv_stats is not None
                else int(np.max(np.asarray(gi.degrees())))
            )
        else:
            max_deg = None
        conn0 = cn.build_state(gi, parts, k, cfg.backend,
                               max_degree=max_deg)
        parts, stats = refine.jet_refine(
            gi,
            parts,
            k,
            lam=cfg.lam,
            c=c,
            phi=cfg.phi,
            backend=cfg.backend,
            patience=cfg.patience,
            max_iter=cfg.max_iter,
            b_max=cfg.b_max,
            variant=cfg.variant,
            rebuild_every=cfg.rebuild_every,
            conn0=conn0,
            max_degree=max_deg,
        )
        size_stats = (
            {kk: lv_stats[kk] for kk in ("n", "m", "n_max", "m_max")}
            if lv_stats is not None
            else {"n": int(gi.n), "m": int(gi.m),
                  "n_max": gi.n_max, "m_max": gi.m_max}
        )
        level_stats.append(
            {"level": i} | size_stats
            | {kk: int(vv) for kk, vv in stats.items()}
        )
        if i > 0:
            fine = levels[i - 1]
            parts = co.project_partition(fine.cmap, parts)
            parts = jnp.where(fine.graph.vertex_mask(), parts, k)
    t_uncoarsen = time.perf_counter() - t0

    # shape_schedule rung 0 is the caller's exact capacity, so the finest
    # parts vector always lines up with g's padding
    assert parts.shape[0] == g.n_max, (parts.shape, g.n_max)

    sizes = metrics.part_sizes(g, parts, k)
    W = g.total_vweight()
    return PartitionResult(
        parts=parts,
        cut=int(metrics.cutsize(g, parts)),
        imbalance=float(metrics.imbalance(sizes, W, k)),
        balanced=bool(metrics.is_balanced(sizes, W, k, cfg.lam)),
        levels=len(levels),
        times={
            "coarsen_s": t_coarsen,
            "initpart_s": t_init,
            "uncoarsen_s": t_uncoarsen,
            "total_s": t_coarsen + t_init + t_uncoarsen,
        },
        level_stats=level_stats,
        config=cfg,
    )


def refine_only(g, parts0, cfg: PartitionConfig) -> PartitionResult:
    """Refinement-effectiveness mode: refine an imported partition on the
    finest graph only (paper §5.1 effectiveness tests)."""
    parts, stats = refine.jet_refine(
        g,
        jnp.asarray(np.asarray(parts0), dtype=jnp.int32),
        cfg.k,
        lam=cfg.lam,
        c=cfg.c_finest,
        phi=cfg.phi,
        backend=cfg.backend,
        patience=cfg.patience,
        max_iter=cfg.max_iter,
        b_max=cfg.b_max,
        variant=cfg.variant,
        rebuild_every=cfg.rebuild_every,
    )
    sizes = metrics.part_sizes(g, parts, cfg.k)
    W = g.total_vweight()
    return PartitionResult(
        parts=parts,
        cut=int(metrics.cutsize(g, parts)),
        imbalance=float(metrics.imbalance(sizes, W, cfg.k)),
        balanced=bool(metrics.is_balanced(sizes, W, cfg.k, cfg.lam)),
        levels=1,
        level_stats=[{kk: int(vv) for kk, vv in stats.items()}],
        config=cfg,
    )
