"""GPU-style coarsening re-derived for TPU: HEM + two-hop matching + contraction.

Paper §3.1: heavy-edge matching first; if >25% of vertices remain unmatched,
add two-hop matches (leaves, twins, relatives).  Contraction (Alg 3.1)
deduplicates coarse edges — the paper uses per-vertex hashtables; we use a
lexicographic sort + segmented sum (TPU idiom, deterministic).

All matching/contraction math is jittable with static padded shapes; only
the *repacking* of the (smaller) coarse graph into tight arrays happens on
host, because array sizes shrink level to level.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph

_KNUTH = jnp.uint32(2654435761)


def _bij_hash(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Invertible-ish 32-bit mix used only for random tie-breaking."""
    h = (x.astype(jnp.uint32) ^ jnp.uint32(seed)) * _KNUTH
    h = h ^ (h >> 16)
    return h


def _seg_pick_dst(elig, value, dst, esrc, n_max, seed):
    """Per-source argmax over eligible edges: max value, random tie-break.

    Returns (cand (N,), has (N,)) — chosen dst per vertex or -1.
    Three deterministic passes: max value; max hash among ties; max dst among
    hash ties (hash collisions only weaken randomization, never correctness).
    """
    NEG = jnp.int32(-1)
    v1 = jnp.where(elig, value, NEG)
    best_v = jax.ops.segment_max(v1, esrc, num_segments=n_max)
    tie1 = elig & (value == best_v[esrc]) & (best_v[esrc] > NEG)
    h = (_bij_hash(dst, seed) >> jnp.uint32(1)).astype(jnp.int32)  # non-negative
    h1 = jnp.where(tie1, h, NEG)
    best_h = jax.ops.segment_max(h1, esrc, num_segments=n_max)
    tie2 = tie1 & (h == best_h[esrc])
    d1 = jnp.where(tie2, dst, NEG)
    cand = jax.ops.segment_max(d1, esrc, num_segments=n_max)
    return cand, cand >= 0


@partial(jax.jit, static_argnames=("rounds",))
def heavy_edge_matching(g: Graph, rounds: int = 8, seed: int = 0) -> jnp.ndarray:
    """Parallel handshake HEM. Returns match (N,): mate id, or -1 unmatched.

    Padding vertices are matched to themselves (excluded from everything).
    """
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    match = jnp.where(vmask, jnp.int32(-1), vid)  # pads self-matched

    def body(r, match):
        unmatched = match < 0
        elig = g.edge_mask() & unmatched[g.esrc] & unmatched[g.adjncy]
        cand, has = _seg_pick_dst(
            elig, g.adjwgt, g.adjncy, g.esrc, n_max, seed * 1000003 + r
        )
        cand = jnp.where(has & unmatched, cand, jnp.int32(-1))
        # mutual handshake
        cand_of_cand = jnp.where(cand >= 0, cand[jnp.clip(cand, 0, n_max - 1)], -2)
        ok = (cand >= 0) & (cand_of_cand == vid)
        return jnp.where(ok, cand, match)

    return jax.lax.fori_loop(0, rounds, body, match)


def _pair_by_key(key: jnp.ndarray, elig: jnp.ndarray, match: jnp.ndarray):
    """Pair eligible vertices sharing a key: sort by key, pair ranks (0,1),(2,3)...

    within each equal-key group (group-aligned so odd-size groups leave
    exactly one vertex unpaired).
    """
    n_max = key.shape[0]
    INF = jnp.int32(2147483647)
    skey = jnp.where(elig, key, INF)
    order = jnp.argsort(skey)  # stable; eligible first by key, then id
    sk = skey[order]
    pos = jnp.arange(n_max, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    group_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    group_start = jnp.zeros((n_max,), jnp.int32).at[group_id].max(
        jnp.where(first, pos, 0)
    )
    rank = pos - group_start[group_id]
    valid = sk < INF
    next_same = jnp.concatenate([sk[1:] == sk[:-1], jnp.zeros((1,), bool)])
    is_lead = valid & (rank % 2 == 0) & next_same
    partner_pos = jnp.where(is_lead, pos + 1, pos - 1)
    is_follow = valid & (rank % 2 == 1)
    paired = is_lead | is_follow
    partner = order[jnp.clip(partner_pos, 0, n_max - 1)]
    new_match = match.at[order].set(
        jnp.where(paired, partner, match[order])
    )
    return new_match


@jax.jit
def twohop_matching(g: Graph, match: jnp.ndarray, mm_max_degree: int = 64):
    """Leaves, twins, relatives (paper §3.1) via sort-pairing."""
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    deg = g.degrees()

    # --- leaves: unmatched degree-1 vertices grouped by their sole neighbor
    unmatched = (match < 0) & vmask
    sole = g.adjncy[jnp.clip(g.xadj[:-1], 0, g.m_max - 1)]
    elig = unmatched & (deg == 1)
    match = _pair_by_key(jnp.where(elig, sole, 0), elig, match)

    # --- twins: unmatched vertices with identical neighborhoods (hash groups)
    unmatched = (match < 0) & vmask
    em = g.edge_mask()
    h1 = jnp.where(em, (_bij_hash(g.adjncy, 11) >> jnp.uint32(2)).astype(jnp.int32), 0)
    h2 = jnp.where(em, (_bij_hash(g.adjncy, 23) >> jnp.uint32(2)).astype(jnp.int32), 0)
    s1 = jax.ops.segment_sum(h1, g.esrc, num_segments=n_max)
    s2 = jax.ops.segment_sum(h2, g.esrc, num_segments=n_max)
    nbhash = ((s1 * jnp.int32(31) + s2) ^ (deg * jnp.int32(0x61C88647))) & jnp.int32(
        0x7FFFFFFF
    )
    elig = unmatched & (deg >= 1)
    match = _pair_by_key(jnp.where(elig, nbhash, 0), elig, match)

    # --- relatives: pair unmatched vertices within a matchmaker's neighborhood
    unmatched = (match < 0) & vmask
    matched = ~unmatched & vmask
    is_mm = matched & (deg <= mm_max_degree)
    # does this matchmaker have unmatched neighbors? (not strictly needed:
    # only unmatched vertices choose keys)
    e_mm = em & is_mm[g.adjncy] & unmatched[g.esrc]
    INF = jnp.int32(2147483647)
    mm_key = jax.ops.segment_min(
        jnp.where(e_mm, g.adjncy, INF), g.esrc, num_segments=n_max
    )
    elig = unmatched & (mm_key < INF)
    match = _pair_by_key(jnp.where(elig, mm_key, 0), elig, match)
    return match


@jax.jit
def coarse_map(g: Graph, match: jnp.ndarray):
    """Map fine vertices to coarse ids. Returns (cmap (N,), nc scalar).

    Singletons map alone; pairs map together; coarse ids ordered by leader id
    (preserves locality).  Padding vertices map to nc.. (ghost tail).
    """
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    mate = jnp.where(match < 0, vid, match)
    mate = jnp.where(vmask, mate, vid)
    leader = jnp.minimum(vid, mate)
    is_leader = (vid == leader) & vmask
    rank = jnp.cumsum(is_leader.astype(jnp.int32)) - 1
    nc = jnp.sum(is_leader.astype(jnp.int32))
    cmap = jnp.where(vmask, rank[leader], nc + (vid - g.n))
    return cmap, nc


@jax.jit
def contract_edges(g: Graph, cmap: jnp.ndarray):
    """Alg 3.1 re-derived: sort coarse (cu, cv) keys, segment-sum duplicates.

    Returns padded run arrays sorted lexicographically by (cu, cv):
      (cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c (N,))
    """
    m_max = g.m_max
    cu = cmap[g.esrc]
    cv = cmap[g.adjncy]
    keep = g.edge_mask() & (cu != cv)
    BIG = jnp.int32(2147483647)
    cu_s = jnp.where(keep, cu, BIG)
    cv_s = jnp.where(keep, cv, BIG)
    # lexicographic (cu, cv) via two stable argsorts
    o1 = jnp.argsort(cv_s, stable=True)
    o2 = jnp.argsort(cu_s[o1], stable=True)
    order = o1[o2]
    su, sv, sw = cu_s[order], cv_s[order], jnp.where(keep, g.adjwgt, 0)[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (su[1:] != su[:-1]) | (sv[1:] != sv[:-1])]
    )
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    w_run = jax.ops.segment_sum(sw, run_id, num_segments=m_max)
    cu_run = jnp.full((m_max,), BIG).at[run_id].min(su)
    cv_run = jnp.full((m_max,), BIG).at[run_id].min(sv)
    run_valid = cu_run != BIG
    n_runs = jnp.sum(run_valid.astype(jnp.int32))
    vwgt_c = jax.ops.segment_sum(g.vwgt, cmap, num_segments=g.n_max)
    return cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c


class CoarsenLevel(NamedTuple):
    graph: Graph
    cmap: jnp.ndarray  # fine vertex -> coarse vertex of the NEXT level


def _round_up(x: int, mult: int = 8) -> int:
    return ((x + mult - 1) // mult) * mult


def coarsen_once(
    g: Graph,
    twohop_threshold: float = 0.25,
    mm_max_degree: int = 64,
    seed: int = 0,
) -> tuple[Graph, jnp.ndarray]:
    """One coarsening level. Returns (coarse graph (tight arrays), cmap)."""
    match = heavy_edge_matching(g, seed=seed)
    n = int(g.n)
    unmatched_frac = float(
        np.asarray(jnp.sum(((match < 0) & g.vertex_mask()).astype(jnp.int32)))
    ) / max(n, 1)
    if unmatched_frac > twohop_threshold:
        match = twohop_matching(g, match, mm_max_degree)
    cmap, nc_dev = coarse_map(g, match)
    cu_run, cv_run, w_run, run_valid, n_runs_dev, vwgt_c = contract_edges(g, cmap)
    nc = int(nc_dev)
    n_runs = int(n_runs_dev)
    # host repack into tight padded arrays
    cu = np.asarray(cu_run)[:n_runs]
    cv = np.asarray(cv_run)[:n_runs]
    w = np.asarray(w_run)[:n_runs]
    vw = np.asarray(vwgt_c)[:nc]
    n_max_c = _round_up(max(nc, 1))
    m_max_c = _round_up(max(n_runs, 1))
    xadj = np.zeros(n_max_c + 1, dtype=np.int64)
    np.add.at(xadj, cu + 1, 1)
    xadj = np.cumsum(xadj)
    xadj_p = np.full(n_max_c + 1, n_runs, dtype=np.int32)
    xadj_p[: nc + 1] = xadj[: nc + 1]
    adjncy_p = np.zeros(m_max_c, dtype=np.int32)
    adjncy_p[:n_runs] = cv
    adjwgt_p = np.zeros(m_max_c, dtype=np.int32)
    adjwgt_p[:n_runs] = w
    vwgt_p = np.zeros(n_max_c, dtype=np.int32)
    vwgt_p[:nc] = vw
    esrc_p = np.zeros(m_max_c, dtype=np.int32)
    esrc_p[:n_runs] = cu
    gc = Graph(
        xadj=jnp.asarray(xadj_p),
        adjncy=jnp.asarray(adjncy_p),
        adjwgt=jnp.asarray(adjwgt_p),
        vwgt=jnp.asarray(vwgt_p),
        esrc=jnp.asarray(esrc_p),
        n=jnp.asarray(nc, dtype=jnp.int32),
        m=jnp.asarray(n_runs, dtype=jnp.int32),
    )
    return gc, cmap


def multilevel_coarsen(
    g: Graph,
    coarse_target: int = 4096,
    max_levels: int = 40,
    stall_ratio: float = 0.95,
    seed: int = 0,
) -> list[CoarsenLevel]:
    """MLCoarsen (Alg 2.1 line 1): list of levels, finest first.

    ``levels[i].cmap`` maps level-i vertices into level-(i+1)'s graph.
    The last entry's cmap is None (coarsest graph).
    """
    levels: list[CoarsenLevel] = []
    cur = g
    for lvl in range(max_levels):
        if int(cur.n) <= coarse_target:
            break
        gc, cmap = coarsen_once(cur, seed=seed + lvl)
        if int(gc.n) > stall_ratio * int(cur.n):  # stalled
            break
        levels.append(CoarsenLevel(graph=cur, cmap=cmap))
        cur = gc
    levels.append(CoarsenLevel(graph=cur, cmap=None))
    return levels


def project_partition(cmap: jnp.ndarray, parts_coarse: jnp.ndarray) -> jnp.ndarray:
    """ProjectPartition (Alg 2.1 line 6): fine parts = coarse parts[cmap]."""
    nc_max = parts_coarse.shape[0]
    return parts_coarse[jnp.clip(cmap, 0, nc_max - 1)]
