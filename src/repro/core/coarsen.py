"""GPU-style coarsening re-derived for TPU: HEM + two-hop matching + contraction.

Paper §3.1: heavy-edge matching first; if >25% of vertices remain unmatched,
add two-hop matches (leaves, twins, relatives).  Contraction (Alg 3.1)
deduplicates coarse edges — the paper uses per-vertex hashtables; we use a
lexicographic sort + segmented sum (TPU idiom, deterministic).

Two coarsening paths share the matching/contraction kernels (DESIGN.md §8):

* **device** (default): :func:`coarsen_level` runs a whole level — HEM
  rounds, the two-hop trigger (``lax.cond`` on the device-computed
  unmatched fraction), ``coarse_map``, ``contract_edges``, and the
  coarse-CSR build — as ONE jitted function with zero host transfers.
  The driver re-buckets the result into a precomputed geometric
  :func:`shape_schedule` of (n_max, m_max) capacities, so kernels compile
  once per capacity rung instead of once per exact size.  The only host
  syncs left are one 3-int32 stat fetch per level (termination check +
  capacity selection).
* **host** (legacy): :func:`coarsen_once` repacks the coarse graph into
  tight arrays on host via numpy — kept as the equivalence/bench baseline.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, csr_from_edge_runs

_KNUTH = jnp.uint32(2654435761)


def _bij_hash(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Invertible-ish 32-bit mix used only for random tie-breaking."""
    h = (x.astype(jnp.uint32) ^ jnp.uint32(seed)) * _KNUTH
    h = h ^ (h >> 16)
    return h


def _seg_pick_dst(elig, value, dst, esrc, n_max, seed):
    """Per-source argmax over eligible edges: max value, random tie-break.

    Returns (cand (N,), has (N,)) — chosen dst per vertex or -1.
    Three deterministic passes: max value; max hash among ties; max dst among
    hash ties (hash collisions only weaken randomization, never correctness).
    """
    NEG = jnp.int32(-1)
    v1 = jnp.where(elig, value, NEG)
    best_v = jax.ops.segment_max(v1, esrc, num_segments=n_max)
    tie1 = elig & (value == best_v[esrc]) & (best_v[esrc] > NEG)
    h = (_bij_hash(dst, seed) >> jnp.uint32(1)).astype(jnp.int32)  # non-negative
    h1 = jnp.where(tie1, h, NEG)
    best_h = jax.ops.segment_max(h1, esrc, num_segments=n_max)
    tie2 = tie1 & (h == best_h[esrc])
    d1 = jnp.where(tie2, dst, NEG)
    cand = jax.ops.segment_max(d1, esrc, num_segments=n_max)
    return cand, cand >= 0


@partial(jax.jit, static_argnames=("rounds",))
def heavy_edge_matching(g: Graph, rounds: int = 8, seed: int = 0) -> jnp.ndarray:
    """Parallel handshake HEM. Returns match (N,): mate id, or -1 unmatched.

    Padding vertices are matched to themselves (excluded from everything).
    """
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    match = jnp.where(vmask, jnp.int32(-1), vid)  # pads self-matched

    def body(r, match):
        unmatched = match < 0
        elig = g.edge_mask() & unmatched[g.esrc] & unmatched[g.adjncy]
        cand, has = _seg_pick_dst(
            elig, g.adjwgt, g.adjncy, g.esrc, n_max, seed * 1000003 + r
        )
        cand = jnp.where(has & unmatched, cand, jnp.int32(-1))
        # mutual handshake
        cand_of_cand = jnp.where(cand >= 0, cand[jnp.clip(cand, 0, n_max - 1)], -2)
        ok = (cand >= 0) & (cand_of_cand == vid)
        return jnp.where(ok, cand, match)

    return jax.lax.fori_loop(0, rounds, body, match)


def _pair_by_key(key: jnp.ndarray, elig: jnp.ndarray, match: jnp.ndarray,
                 seed: int = 0):
    """Pair eligible vertices sharing a key: sort by key, pair ranks (0,1),(2,3)...

    within each equal-key group (group-aligned so odd-size groups leave
    exactly one vertex unpaired).  Within a group, vertices are ordered by a
    seeded hash of their id, so which pairs form varies per level seed.
    """
    n_max = key.shape[0]
    INF = jnp.int32(2147483647)
    skey = jnp.where(elig, key, INF)
    vid = jnp.arange(n_max, dtype=jnp.int32)
    h = (_bij_hash(vid, seed) >> jnp.uint32(1)).astype(jnp.int32)
    o1 = jnp.argsort(h, stable=True)
    o2 = jnp.argsort(skey[o1], stable=True)
    order = o1[o2]  # eligible first by key; within a key, by seeded hash
    sk = skey[order]
    pos = jnp.arange(n_max, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    group_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    group_start = jnp.zeros((n_max,), jnp.int32).at[group_id].max(
        jnp.where(first, pos, 0)
    )
    rank = pos - group_start[group_id]
    valid = sk < INF
    next_same = jnp.concatenate([sk[1:] == sk[:-1], jnp.zeros((1,), bool)])
    is_lead = valid & (rank % 2 == 0) & next_same
    partner_pos = jnp.where(is_lead, pos + 1, pos - 1)
    is_follow = valid & (rank % 2 == 1)
    paired = is_lead | is_follow
    partner = order[jnp.clip(partner_pos, 0, n_max - 1)]
    new_match = match.at[order].set(
        jnp.where(paired, partner, match[order])
    )
    return new_match


@jax.jit
def twohop_matching(
    g: Graph, match: jnp.ndarray, mm_max_degree: int = 64, seed: int = 0
):
    """Leaves, twins, relatives (paper §3.1) via sort-pairing.

    ``seed`` salts the twin neighborhood hashes so each level's twin/relative
    pairing is decorrelated from every other level's.
    """
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    deg = g.degrees()

    # --- leaves: unmatched degree-1 vertices grouped by their sole neighbor
    unmatched = (match < 0) & vmask
    sole = g.adjncy[jnp.clip(g.xadj[:-1], 0, g.m_max - 1)]
    elig = unmatched & (deg == 1)
    match = _pair_by_key(jnp.where(elig, sole, 0), elig, match, seed * 4 + 1)

    # --- twins: unmatched vertices with identical neighborhoods (hash groups)
    unmatched = (match < 0) & vmask
    em = g.edge_mask()
    s_a = seed * 1000003 + 11
    s_b = seed * 1000003 + 23
    h1 = jnp.where(em, (_bij_hash(g.adjncy, s_a) >> jnp.uint32(2)).astype(jnp.int32), 0)
    h2 = jnp.where(em, (_bij_hash(g.adjncy, s_b) >> jnp.uint32(2)).astype(jnp.int32), 0)
    s1 = jax.ops.segment_sum(h1, g.esrc, num_segments=n_max)
    s2 = jax.ops.segment_sum(h2, g.esrc, num_segments=n_max)
    nbhash = ((s1 * jnp.int32(31) + s2) ^ (deg * jnp.int32(0x61C88647))) & jnp.int32(
        0x7FFFFFFF
    )
    elig = unmatched & (deg >= 1)
    match = _pair_by_key(jnp.where(elig, nbhash, 0), elig, match, seed * 4 + 2)

    # --- relatives: pair unmatched vertices within a matchmaker's neighborhood
    unmatched = (match < 0) & vmask
    matched = ~unmatched & vmask
    is_mm = matched & (deg <= mm_max_degree)
    # does this matchmaker have unmatched neighbors? (not strictly needed:
    # only unmatched vertices choose keys)
    e_mm = em & is_mm[g.adjncy] & unmatched[g.esrc]
    INF = jnp.int32(2147483647)
    mm_key = jax.ops.segment_min(
        jnp.where(e_mm, g.adjncy, INF), g.esrc, num_segments=n_max
    )
    elig = unmatched & (mm_key < INF)
    match = _pair_by_key(jnp.where(elig, mm_key, 0), elig, match, seed * 4 + 3)
    return match


@jax.jit
def coarse_map(g: Graph, match: jnp.ndarray):
    """Map fine vertices to coarse ids. Returns (cmap (N,), nc scalar).

    Singletons map alone; pairs map together; coarse ids ordered by leader id
    (preserves locality).  Padding vertices map to nc.. (ghost tail).
    """
    n_max = g.n_max
    vid = jnp.arange(n_max, dtype=jnp.int32)
    vmask = g.vertex_mask()
    mate = jnp.where(match < 0, vid, match)
    mate = jnp.where(vmask, mate, vid)
    leader = jnp.minimum(vid, mate)
    is_leader = (vid == leader) & vmask
    rank = jnp.cumsum(is_leader.astype(jnp.int32)) - 1
    nc = jnp.sum(is_leader.astype(jnp.int32))
    cmap = jnp.where(vmask, rank[leader], nc + (vid - g.n))
    return cmap, nc


@jax.jit
def contract_edges(g: Graph, cmap: jnp.ndarray):
    """Alg 3.1 re-derived: sort coarse (cu, cv) keys, segment-sum duplicates.

    Returns padded run arrays sorted lexicographically by (cu, cv):
      (cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c (N,))
    """
    m_max = g.m_max
    cu = cmap[g.esrc]
    cv = cmap[g.adjncy]
    keep = g.edge_mask() & (cu != cv)
    BIG = jnp.int32(2147483647)
    cu_s = jnp.where(keep, cu, BIG)
    cv_s = jnp.where(keep, cv, BIG)
    # lexicographic (cu, cv) via two stable argsorts
    o1 = jnp.argsort(cv_s, stable=True)
    o2 = jnp.argsort(cu_s[o1], stable=True)
    order = o1[o2]
    su, sv, sw = cu_s[order], cv_s[order], jnp.where(keep, g.adjwgt, 0)[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (su[1:] != su[:-1]) | (sv[1:] != sv[:-1])]
    )
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    w_run = jax.ops.segment_sum(sw, run_id, num_segments=m_max)
    cu_run = jnp.full((m_max,), BIG).at[run_id].min(su)
    cv_run = jnp.full((m_max,), BIG).at[run_id].min(sv)
    run_valid = cu_run != BIG
    n_runs = jnp.sum(run_valid.astype(jnp.int32))
    vwgt_c = jax.ops.segment_sum(g.vwgt, cmap, num_segments=g.n_max)
    return cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c


class CoarsenLevel(NamedTuple):
    graph: Graph
    cmap: jnp.ndarray  # fine vertex -> coarse vertex of the NEXT level
    stats: dict | None = None  # host ints: n, m, max_degree, n_max, m_max


def _round_up(x: int, mult: int = 8) -> int:
    return ((x + mult - 1) // mult) * mult


def coarsen_once(
    g: Graph,
    twohop_threshold: float = 0.25,
    mm_max_degree: int = 64,
    seed: int = 0,
) -> tuple[Graph, jnp.ndarray]:
    """One coarsening level, legacy host-repack path.

    Returns (coarse graph (tight arrays), cmap).  Kept as the equivalence
    baseline for :func:`coarsen_level`; prefer the device path in drivers.
    """
    match = heavy_edge_matching(g, seed=seed)
    n = int(g.n)
    unmatched = int(
        np.asarray(jnp.sum(((match < 0) & g.vertex_mask()).astype(jnp.int32)))
    )
    # float32 on purpose: bit-identical to coarsen_level's on-device trigger
    # (a float64 division here could disagree near the threshold for huge n)
    frac = np.float32(unmatched) / np.float32(max(n, 1))
    if frac > np.float32(twohop_threshold):
        match = twohop_matching(g, match, mm_max_degree, seed)
    cmap, nc_dev = coarse_map(g, match)
    cu_run, cv_run, w_run, run_valid, n_runs_dev, vwgt_c = contract_edges(g, cmap)
    nc = int(nc_dev)
    n_runs = int(n_runs_dev)
    # host repack into tight padded arrays
    cu = np.asarray(cu_run)[:n_runs]
    cv = np.asarray(cv_run)[:n_runs]
    w = np.asarray(w_run)[:n_runs]
    vw = np.asarray(vwgt_c)[:nc]
    n_max_c = _round_up(max(nc, 1))
    m_max_c = _round_up(max(n_runs, 1))
    xadj = np.zeros(n_max_c + 1, dtype=np.int64)
    np.add.at(xadj, cu + 1, 1)
    xadj = np.cumsum(xadj)
    xadj_p = np.full(n_max_c + 1, n_runs, dtype=np.int32)
    xadj_p[: nc + 1] = xadj[: nc + 1]
    adjncy_p = np.zeros(m_max_c, dtype=np.int32)
    adjncy_p[:n_runs] = cv
    adjwgt_p = np.zeros(m_max_c, dtype=np.int32)
    adjwgt_p[:n_runs] = w
    vwgt_p = np.zeros(n_max_c, dtype=np.int32)
    vwgt_p[:nc] = vw
    esrc_p = np.zeros(m_max_c, dtype=np.int32)
    esrc_p[:n_runs] = cu
    gc = Graph(
        xadj=jnp.asarray(xadj_p),
        adjncy=jnp.asarray(adjncy_p),
        adjwgt=jnp.asarray(adjwgt_p),
        vwgt=jnp.asarray(vwgt_p),
        esrc=jnp.asarray(esrc_p),
        n=jnp.asarray(nc, dtype=jnp.int32),
        m=jnp.asarray(n_runs, dtype=jnp.int32),
    )
    return gc, cmap


# ---------------------------------------------------------------------------
# Device-resident coarsening (DESIGN.md §8)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("hem_rounds",))
def coarsen_level(
    g: Graph,
    seed: int = 0,
    twohop_threshold: float = 0.25,
    mm_max_degree: int = 64,
    hem_rounds: int = 8,
) -> tuple[Graph, jnp.ndarray]:
    """One whole coarsening level as a single jitted function — no host syncs.

    HEM rounds, the two-hop trigger (``lax.cond`` on the device-computed
    unmatched fraction), ``coarse_map``, ``contract_edges``, and the
    device-side coarse-CSR build all run in one XLA program.  The coarse
    graph comes back padded at the FINE graph's capacities (``nc <= n`` and
    ``n_runs <= m`` guarantee they fit); the driver re-buckets it with
    :meth:`Graph.with_capacity` after reading the level stats.

    ``seed``/``twohop_threshold``/``mm_max_degree`` are traced, so changing
    them never recompiles; only the capacity bucket (array shapes) does.
    """
    match = heavy_edge_matching(g, rounds=hem_rounds, seed=seed)
    unmatched = jnp.sum(((match < 0) & g.vertex_mask()).astype(jnp.int32))
    frac = unmatched.astype(jnp.float32) / jnp.maximum(g.n, 1).astype(jnp.float32)
    match = jax.lax.cond(
        frac > twohop_threshold,
        lambda m: twohop_matching(g, m, mm_max_degree, seed),
        lambda m: m,
        match,
    )
    cmap, nc = coarse_map(g, match)
    cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c = contract_edges(g, cmap)
    gc = csr_from_edge_runs(
        cu_run, cv_run, w_run, run_valid, n_runs, vwgt_c, nc,
        n_max=g.n_max, m_max=g.m_max,
    )
    return gc, cmap


@jax.jit
def _level_stats_dev(g: Graph) -> jnp.ndarray:
    """(n, m, max_degree) as one int32 device array — fetched in ONE transfer."""
    return jnp.stack(
        [g.n, g.m, jnp.max(g.degrees()).astype(jnp.int32)]
    ).astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_max", "m_max"))
def _rebucket(g: Graph, n_max: int, m_max: int) -> Graph:
    return g.with_capacity(n_max, m_max)


def _fetch_stats(g: Graph) -> dict:
    n, m, max_deg = (int(x) for x in np.asarray(_level_stats_dev(g)))
    return {"n": n, "m": m, "max_degree": max_deg,
            "n_max": g.n_max, "m_max": g.m_max}


def shape_schedule(
    n_max: int,
    m_max: int,
    ratio: float = 1.6,
    safety: float = 1.25,
    stall_ratio: float = 0.95,
    align: int = 64,
    floor: int = 64,
) -> tuple[tuple[int, int], ...]:
    """Geometric capacity ladder for the device coarsening path.

    Each rung shrinks both capacities by ``min(safety / ratio, stall_ratio)``
    — HEM halves at best (``ratio``), rarely that fast (``safety`` headroom),
    and a level shrinking less than ``stall_ratio`` terminates coarsening
    anyway, so a smaller per-rung factor would only create rungs no level
    can ever land in.  Rungs are aligned so distinct graphs share buckets
    (and therefore compiled kernels).  Descending; rung 0 always fits the
    input graph.
    """
    if ratio <= 0 or safety <= 0 or align <= 0:
        raise ValueError(
            f"ratio/safety/align must be positive, got {ratio}/{safety}/{align}"
        )
    f = min(safety / ratio, stall_ratio)
    if not 0.0 < f < 1.0:
        raise ValueError(
            f"per-rung shrink min(safety/ratio, stall_ratio)={f} must be in "
            f"(0, 1), got ratio={ratio} safety={safety} "
            f"stall_ratio={stall_ratio}"
        )
    # Rung 0 is the input's EXACT capacity (not aligned up): the finest
    # level must keep the caller's padding so the final parts vector lines
    # up with the caller's graph.
    rungs = [(max(n_max, 1), max(m_max, 1))]
    n, m = rungs[0]
    while n > floor or m > floor:
        n = max(int(n * f), 1)
        m = max(int(m * f), 1)
        rung = (_round_up(n, align), _round_up(m, align))
        if rung[0] <= rungs[-1][0] and rung[1] <= rungs[-1][1]:
            if rung != rungs[-1]:
                rungs.append(rung)
        # alignment can lift a tiny rung above its predecessor — skip it
    return tuple(rungs)


def select_capacity(
    schedule: tuple[tuple[int, int], ...], n: int, m: int
) -> tuple[int, int]:
    """Smallest fitting capacity, chosen per axis.

    Vertex and edge counts shrink at different rates (meshes lose vertices
    faster than edges early on), so each axis picks its own smallest
    fitting rung — a joint pick would strand a level in an oversized
    bucket whenever one axis lags.  Rung 0 always fits both.
    """
    n_cap = min(nc for nc, _ in schedule if nc >= n)
    m_cap = min(mc for _, mc in schedule if mc >= m)
    return (n_cap, m_cap)


# ---------------------------------------------------------------------------
# Fleet coarsening — vmapped levels over a shape bucket (DESIGN.md §10)
# ---------------------------------------------------------------------------


class FleetLevel(NamedTuple):
    """One level of a bucket's batched hierarchy.

    ``graph`` is a stacked ``(B, ...)`` :class:`Graph`; ``cmap`` is
    ``(B, n_max)`` into the next level (identity rows for frozen lanes;
    None at the coarsest level).  ``active[b]`` says lane ``b`` is still
    *real* at this level — its own hierarchy reaches this deep, so the
    uncoarsening driver runs refinement for it here; frozen lanes pass
    their partition through untouched.  ``stats`` holds per-lane host
    numbers (``n``/``m``/``max_degree`` as (B,) arrays) plus the shared
    ``n_max``/``m_max`` capacity ints.
    """

    graph: Graph
    cmap: jnp.ndarray | None
    active: np.ndarray
    stats: dict | None


@jax.jit
def _stats_fleet(gb: Graph) -> jnp.ndarray:
    """(B, 3) int32 per-lane (n, m, max_degree) — one transfer per level."""
    return jax.vmap(_level_stats_dev)(gb)


@jax.jit
def _coarsen_step_fleet(gb: Graph, seed, twohop_threshold, mm_max_degree):
    """One coarsening level for every lane of a bucket, plus its stats.

    ``seed``/thresholds are traced scalars shared by all lanes, exactly as
    the standalone driver passes them — a lane's matching trajectory is the
    one its solo run would walk (the two-hop ``lax.cond`` select-masks per
    lane under vmap).
    """

    def one(g):
        gc, cmap = coarsen_level(g, seed, twohop_threshold, mm_max_degree)
        return gc, cmap, _level_stats_dev(gc)

    return jax.vmap(one)(gb)


@partial(jax.jit, static_argnames=("n_max", "m_max"))
def _freeze_rebucket_fleet(
    gc: Graph, cmap: jnp.ndarray, fine: Graph, success: jnp.ndarray,
    *, n_max: int, m_max: int,
) -> tuple[Graph, jnp.ndarray]:
    """Select-mask failed lanes back to their fine graph, then re-bucket.

    Lanes that terminated (reached ``coarse_target`` earlier, or stalled
    this level) keep their fine graph frozen with an identity cmap — the
    batched analogue of the standalone driver's ``break``.  All lanes are
    then re-bucketed to the shared next capacity, which is selected to fit
    the batch max per axis, so frozen lanes always fit.
    """

    def one(gc_i, cmap_i, fine_i, s):
        g = jax.tree_util.tree_map(
            lambda a, b: jnp.where(s, a, b), gc_i, fine_i
        )
        ident = jnp.arange(cmap_i.shape[0], dtype=jnp.int32)
        return g.with_capacity(n_max, m_max), jnp.where(s, cmap_i, ident)

    return jax.vmap(one)(gc, cmap, fine, success)


def multilevel_coarsen_fleet(
    gb: Graph,
    schedule: tuple[tuple[int, int], ...],
    coarse_target: int = 4096,
    max_levels: int = 40,
    stall_ratio: float = 0.95,
    seed: int = 0,
    twohop_threshold: float = 0.25,
    mm_max_degree: int = 64,
) -> list[FleetLevel]:
    """Batched MLCoarsen over one shape bucket: list of levels, finest first.

    The whole bucket advances in lockstep — batch level ``i`` is every
    lane's own level ``i`` — but each lane terminates on ITS own schedule
    (``coarse_target`` / ``stall_ratio`` / ``max_levels``), mirroring the
    standalone driver's per-graph ``break``s via select-masking: a
    terminated lane's graph rides along frozen (identity cmap) and its
    ``active`` flag goes false for all deeper levels.  Per-level host syncs
    are one (B, 3) stat fetch, same cadence as the standalone driver.
    """
    B = gb.vwgt.shape[0]
    n_max, m_max = gb.vwgt.shape[1], gb.adjncy.shape[1]
    st0 = np.asarray(_stats_fleet(gb))
    n, m, md = (st0[:, j].astype(np.int64) for j in range(3))
    if schedule[0][0] < n_max or schedule[0][1] < m_max:
        raise ValueError(
            f"schedule rung 0 {schedule[0]} is below the bucket capacity "
            f"({n_max}, {m_max}) — bucket with bucket_graphs first"
        )
    dead = np.zeros(B, bool)
    depth = np.zeros(B, np.int64)
    raw: list[tuple] = []
    for lvl in range(max_levels):
        active = ~dead & (n > coarse_target)
        if not active.any():
            break
        gc, cmap, stc = _coarsen_step_fleet(
            gb, seed + lvl, twohop_threshold, mm_max_degree
        )
        stc = np.asarray(stc).astype(np.int64)  # the per-level host sync
        stalled = stc[:, 0] > stall_ratio * n
        success = active & ~stalled
        dead |= active & stalled
        if not success.any():
            break
        new_n = np.where(success, stc[:, 0], n)
        new_m = np.where(success, stc[:, 1], m)
        new_md = np.where(success, stc[:, 2], md)
        cap = select_capacity(schedule, int(new_n.max()), int(new_m.max()))
        gb2, cmap = _freeze_rebucket_fleet(
            gc, cmap, gb, jnp.asarray(success), n_max=cap[0], m_max=cap[1]
        )
        raw.append((gb, cmap,
                    {"n": n, "m": m, "max_degree": md,
                     "n_max": n_max, "m_max": m_max}))
        depth += success
        gb, n, m, md = gb2, new_n, new_m, new_md
        n_max, m_max = cap
    raw.append((gb, None, {"n": n, "m": m, "max_degree": md,
                           "n_max": n_max, "m_max": m_max}))
    return [
        FleetLevel(graph=g, cmap=c, active=depth >= i, stats=s)
        for i, (g, c, s) in enumerate(raw)
    ]


def multilevel_coarsen(
    g: Graph,
    coarse_target: int = 4096,
    max_levels: int = 40,
    stall_ratio: float = 0.95,
    seed: int = 0,
    mode: str = "device",
    schedule: tuple[tuple[int, int], ...] | None = None,
    twohop_threshold: float = 0.25,
    mm_max_degree: int = 64,
    bucket_ratio: float = 1.6,
    bucket_safety: float = 1.25,
    bucket_align: int = 64,
) -> list[CoarsenLevel]:
    """MLCoarsen (Alg 2.1 line 1): list of levels, finest first.

    ``levels[i].cmap`` maps level-i vertices into level-(i+1)'s graph.
    The last entry's cmap is None (coarsest graph).  Every level carries
    host ``stats`` (n, m, max_degree, capacities) captured in one per-level
    transfer, so downstream consumers (ELL backend, ConnState build) never
    re-sync.

    ``mode="device"`` (default) runs each level via :func:`coarsen_level`
    and re-buckets results along ``schedule`` (a :func:`shape_schedule`
    ladder); the only host decisions are the termination check and the
    capacity selection.  ``mode="host"`` is the legacy per-level numpy
    repack via :func:`coarsen_once`.
    """
    if mode not in ("device", "host"):
        raise ValueError(f"unknown coarsen mode {mode!r}")
    cur = g
    stats0 = _fetch_stats(cur)
    if mode == "device":
        if schedule is None:
            schedule = shape_schedule(
                g.n_max, g.m_max, ratio=bucket_ratio, safety=bucket_safety,
                stall_ratio=stall_ratio, align=bucket_align,
            )
        if schedule[0][0] < stats0["n"] or schedule[0][1] < stats0["m"]:
            raise ValueError(
                f"schedule rung 0 {schedule[0]} cannot hold the input graph "
                f"(n={stats0['n']}, m={stats0['m']}) — with_capacity would "
                "silently truncate real vertices/edges"
            )
        if (cur.n_max, cur.m_max) != schedule[0]:
            cur = _rebucket(cur, *schedule[0])
            stats0 = {**stats0, "n_max": schedule[0][0],
                      "m_max": schedule[0][1]}

    def step(fine, lvl):
        """One level + its stats; per-level host syncs live here."""
        if mode == "host":
            gc, cmap = coarsen_once(
                fine, twohop_threshold=twohop_threshold,
                mm_max_degree=mm_max_degree, seed=seed + lvl,
            )
            return gc, cmap, _fetch_stats(gc)
        gc, cmap = coarsen_level(
            fine, seed=seed + lvl, twohop_threshold=twohop_threshold,
            mm_max_degree=mm_max_degree,
        )
        # The ONLY device-path host sync: 3 int32 (termination + capacity).
        st = _fetch_stats(gc)
        cap = select_capacity(schedule, st["n"], st["m"])
        if cap != (gc.n_max, gc.m_max):
            gc = _rebucket(gc, *cap)
            st = {**st, "n_max": cap[0], "m_max": cap[1]}
        return gc, cmap, st

    levels: list[CoarsenLevel] = []
    stats = stats0
    for lvl in range(max_levels):
        if stats["n"] <= coarse_target:
            break
        gc, cmap, stats_c = step(cur, lvl)
        if stats_c["n"] > stall_ratio * stats["n"]:  # stalled
            break
        levels.append(CoarsenLevel(graph=cur, cmap=cmap, stats=stats))
        cur, stats = gc, stats_c
    levels.append(CoarsenLevel(graph=cur, cmap=None, stats=stats))
    return levels


def project_partition(cmap: jnp.ndarray, parts_coarse: jnp.ndarray) -> jnp.ndarray:
    """ProjectPartition (Alg 2.1 line 6): fine parts = coarse parts[cmap]."""
    nc_max = parts_coarse.shape[0]
    return parts_coarse[jnp.clip(cmap, 0, nc_max - 1)]
