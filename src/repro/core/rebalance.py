"""Jetr rebalancing — weak (Alg 4.3) and strong variants, slot bucketing (Eq 4.5).

The paper's GPU bucket insertion uses atomic counters + rho minibuckets; a
TPU has no equivalent, so we realize the *same partial order* with a stable
sort on (part, slot) keys, then select eviction prefixes with a segmented
cumulative sum.  Theorem 4.1's 2x bound depends only on the slot
quantization, which we keep verbatim — tests/test_properties.py checks it.

Batch polymorphism (DESIGN.md §9): both move kernels are pure functions of
arrays (stable sorts, cumsums, searchsorted — all with per-row vmap rules),
so they lift under ``jax.vmap`` over a trial axis unchanged; per-trial
sizes/limits come in through the threaded ConnState and stay traced.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core import metrics
from repro.core.graph import Graph

NSLOT = 36  # slot(x) in [0, 2+floor(log2(2^31))] = [0, 33]


def slot(loss: jnp.ndarray) -> jnp.ndarray:
    """Eq 4.5: log2 bucketing of the loss value."""
    lg = jnp.floor(
        jnp.log2(jnp.maximum(loss.astype(jnp.float32), 1.0))
    ).astype(jnp.int32)
    return jnp.where(loss > 0, 2 + lg, jnp.where(loss == 0, 1, 0))


def _dest_caps(sizes: jnp.ndarray, limit: jnp.ndarray, total_w: jnp.ndarray, k: int):
    """Oversized set A, valid-destination set B, and sigma (deadzone top).

    sigma = midpoint of (opt, limit): destinations may fill up to sigma, so
    a destination can never be pushed past the limit into A by one Jetrs
    round of size <= limit - sigma.
    """
    opt = total_w // k
    sigma = (limit.astype(jnp.int32) + opt.astype(jnp.int32)) // 2
    over = sizes > limit
    valid = (sizes <= sigma) & ~over
    return over, valid, sigma, opt


def _rank_to_part(valid_parts: jnp.ndarray, k: int):
    """part_of_rank[r] = r-th valid part id; num_valid."""
    rank = jnp.cumsum(valid_parts.astype(jnp.int32)) - 1
    num_valid = jnp.sum(valid_parts.astype(jnp.int32))
    part_of_rank = jnp.zeros((k,), jnp.int32).at[
        jnp.where(valid_parts, rank, k - 1)
    ].max(jnp.where(valid_parts, jnp.arange(k, dtype=jnp.int32), 0))
    return part_of_rank, num_valid


def _evict_prefix(g: Graph, parts, k, movable, slots, sizes, limit):
    """Stable sort by (part, slot); pick per-part prefixes with weight just
    covering size - limit (Alg 4.3 lines 19-28, Eq 4.4).

    Returns (evict (N,) bool, order (N,), ecum_before (N,) cumulative evicted
    weight, in sorted space, for the cookie-cutter).
    """
    n_max = g.n_max
    INF = jnp.int32(2147483647)
    key = jnp.where(movable, parts * NSLOT + slots, INF)
    order = jnp.argsort(key)  # stable: (part, slot), then vertex id
    mov_s = movable[order]
    seg = jnp.where(mov_s, parts[order], k)
    w_s = jnp.where(mov_s, g.vwgt[order], 0)
    cum = jnp.cumsum(w_s)
    cum_before = cum - w_s
    pos = jnp.arange(n_max, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), seg[1:] != seg[:-1]])
    part_off = jnp.zeros((k + 1,), jnp.int32).at[seg].max(
        jnp.where(first, cum_before, 0)
    )
    within_before = cum_before - part_off[seg]
    need = jnp.maximum(sizes - limit, 0)  # weight to shed per part
    need_s = need[jnp.clip(seg, 0, k - 1)]
    evict_s = mov_s & (within_before < need_s)
    evict = jnp.zeros((n_max,), bool).at[order].set(evict_s)
    # cumulative evicted weight before each sorted position (for Jetrs)
    ew = jnp.where(evict_s, w_s, 0)
    ecum_before = jnp.cumsum(ew) - ew
    return evict, order, evict_s, ecum_before


def _common(g: Graph, conn: cn.ConnState, parts, k, lam):
    sizes = conn.sizes
    W = g.total_vweight()
    limit = metrics.size_limit(W, k, lam)
    over, valid, sigma, opt = _dest_caps(sizes, limit, W, k)
    vmask = g.vertex_mask()
    pclip = jnp.clip(parts, 0, k - 1)
    in_over = over[pclip] & vmask & (parts < k)
    # weight restriction (paper end of §4.2.2)
    surplus = (sizes[pclip] - opt).astype(jnp.float32)
    movable = in_over & (g.vwgt.astype(jnp.float32) <= 1.5 * surplus)
    return sizes, limit, over, valid, sigma, opt, movable


def _state_and_queries(g, parts, k, backend, conn, queries):
    """Fill in state/queries for direct (non-loop) callers."""
    if conn is None:
        conn = cn.build_state(g, parts, k, backend)
    if queries is None:
        queries = cn.state_queries(g, conn, parts, k, backend)
    return conn, queries


def jetrw_moves(g: Graph, parts, k: int, lam: float, backend: str = "dense",
                conn: cn.ConnState | None = None, queries=None):
    """Weak rebalancing (Alg 4.3): evictees go to their best valid part.

    ``conn``/``queries`` come from the threaded refinement state; standalone
    callers may omit them and pay for a one-off build.
    """
    conn, q = _state_and_queries(g, parts, k, backend, conn, queries)
    sizes, limit, over, valid, sigma, opt, movable = _common(g, conn, parts,
                                                             k, lam)
    best_conn, best_part, has = cn.rw_queries(g, conn, k, valid, backend)
    # fallback destination: pseudo-random valid part (deterministic hash)
    part_of_rank, num_valid = _rank_to_part(valid, k)
    vid = jnp.arange(g.n_max, dtype=jnp.uint32)
    r = ((vid * jnp.uint32(2654435761)) >> jnp.uint32(8)).astype(jnp.int32)
    r = r % jnp.maximum(num_valid, 1)
    rand_part = part_of_rank[jnp.clip(r, 0, k - 1)]
    # last-resort (no valid part at all): smallest part
    argmin_part = jnp.argmin(sizes).astype(jnp.int32)
    dest = jnp.where(has, best_part, jnp.where(num_valid > 0, rand_part, argmin_part))
    loss = q.conn_self - best_conn  # conn to valid dest is best_conn (0 if none)
    slots = slot(loss)
    evict, order, evict_s, _ = _evict_prefix(g, parts, k, movable, slots, sizes, limit)
    return evict, dest.astype(jnp.int32)


def jetrs_moves(g: Graph, parts, k: int, lam: float, backend: str = "dense",
                conn: cn.ConnState | None = None, queries=None):
    """Strong rebalancing: cookie-cutter destination overlay (one shot)."""
    conn, q = _state_and_queries(g, parts, k, backend, conn, queries)
    sizes, limit, over, valid, sigma, opt, movable = _common(g, conn, parts,
                                                             k, lam)
    s_conn, cnt = cn.rs_queries(g, conn, k, valid, backend)
    mean_conn = jnp.where(cnt > 0, s_conn // jnp.maximum(cnt, 1), 0)
    loss = q.conn_self - mean_conn  # Eq 4.10 (sign per Alg 4.3 convention)
    slots = slot(loss)
    evict, order, evict_s, ecum_before = _evict_prefix(
        g, parts, k, movable, slots, sizes, limit
    )
    # capacities of valid destinations up to sigma
    cap = jnp.where(valid, jnp.maximum(sigma - sizes, 0), 0)
    ccap = jnp.cumsum(cap)
    total_cap = ccap[-1]
    x = jnp.minimum(ecum_before, jnp.maximum(total_cap - 1, 0))
    dest_s = jnp.searchsorted(ccap, x, side="right").astype(jnp.int32)
    dest_s = jnp.clip(dest_s, 0, k - 1)
    # safety: if total capacity is zero, send to smallest part
    argmin_part = jnp.argmin(sizes).astype(jnp.int32)
    dest_s = jnp.where(total_cap > 0, dest_s, argmin_part)
    dest = jnp.zeros((g.n_max,), jnp.int32).at[order].set(dest_s)
    return evict, dest
