"""Vertex-part connectivity — the Jet refinement data structure (paper §4.3).

The paper uses per-vertex GPU hashtables sized ``min(k, degree(v))``.  TPUs
have no efficient random-access atomics, so we provide two bulk array
backends behind one interface:

* ``dense``  — an (N, k+1) scatter-add connectivity matrix.  O(n*k) memory,
  fastest for small/medium k; every query is a masked row reduction.
* ``sorted`` — sorts per-edge (src, part) keys and segment-sums runs, then
  reduces runs per vertex.  O(m) memory like the paper's structure, fully
  deterministic (the paper documents hashtable-insert races as its source of
  nondeterminism; a stable sort has none).

Both backends answer the queries refinement needs (paper §4.3):
  1. conn(v, P_s(v)) and the best alternative part + its connectivity (Jetlp)
  2. best *valid-destination* part + connectivity (Jetrw)
  3. sum & count of connectivity over valid destinations (Jetrs)
  4. update after a move list (paper Alg 4.4)

Stateful interface (DESIGN.md §3): :class:`ConnState` packages the backend
structure together with delta-maintained part sizes and the current cutsize.
It is built once per level (:func:`build_state`), threaded through the
refinement ``lax.while_loop``, advanced after each move list with
Alg 4.4-style scatter-add deltas (:func:`apply_moves`), and refreshed from
scratch only on the ``rebuild_every`` escape hatch (:func:`rebuild_state`).
Incremental and rebuilt state agree bit-exactly (integer arithmetic only);
tests/test_conn_state.py asserts this.

Batch polymorphism (DESIGN.md §§9-10): every function here is a pure jitted
function of arrays — no shape-dependent Python branches on values, no host
reads of traced quantities — so the whole interface lifts under ``jax.vmap``
over a leading trial axis, and again over a leading graph axis (the fleet
path vmaps graphs × trials).  Inside a trial-vmapped trace only genuinely
per-trial state grows the batch dimension (``mat`` / ``edge_dst_part`` /
``ell_parts``, ``sizes``, ``cut``); the static ELL adjacency
(``ell_nbr``/``ell_wgt``) and the graph stay unbatched, and the while-loop
carry fixpoint keeps them so.  Under the outer graph vmap the graph and the
ELL adjacency DO carry the B axis (each lane is a different graph), stored
once per lane, not once per (lane, trial).  The dense backend's batched
matrix is O(B·T·n·k) memory — steer large-T/B runs to ``sorted``/``ell``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

BACKENDS = ("dense", "sorted", "ell")


class ConnQueries(NamedTuple):
    """Per-vertex connectivity answers, all shape (N,)."""

    conn_self: jnp.ndarray   # conn(v, P_s(v))
    best_part: jnp.ndarray   # argmax_{p != P_s(v)} conn(v, p); == k if none
    best_conn: jnp.ndarray   # its connectivity (0 if none)


# ---------------------------------------------------------------------------
# dense backend
# ---------------------------------------------------------------------------

def conn_matrix(g: Graph, parts: jnp.ndarray, k: int) -> jnp.ndarray:
    """(N, k+1) connectivity matrix via scatter-add over directed edges.

    Column k is the ghost part (padding); padding edges carry weight 0 so
    they contribute nothing wherever they scatter.
    """
    dst_part = parts[g.adjncy]
    mat = jnp.zeros((g.n_max, k + 1), dtype=jnp.int32)
    return mat.at[g.esrc, dst_part].add(g.adjwgt)


def queries_from_matrix(mat: jnp.ndarray, parts: jnp.ndarray, k: int) -> ConnQueries:
    n_max = mat.shape[0]
    rows = jnp.arange(n_max, dtype=jnp.int32)
    conn_self = mat[rows, parts]
    cols = jnp.arange(k + 1, dtype=jnp.int32)
    # mask own part and the ghost column
    masked = jnp.where(
        (cols[None, :] == parts[:, None]) | (cols[None, :] == k), -1, mat
    )
    best_part = jnp.argmax(masked, axis=1).astype(jnp.int32)
    best_conn = jnp.max(masked, axis=1)
    none = best_conn <= 0  # weights positive: conn 0 means not adjacent
    best_part = jnp.where(none, k, best_part)
    best_conn = jnp.where(none, 0, best_conn)
    return ConnQueries(conn_self, best_part, best_conn)


@partial(jax.jit, static_argnames=("k",))
def dense_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    return queries_from_matrix(conn_matrix(g, parts, k), parts, k)


# ---------------------------------------------------------------------------
# sorted backend — O(m) memory
# ---------------------------------------------------------------------------

_INVALID = jnp.uint32(0xFFFFFFFF)


def runs_from_dst_part(g: Graph, dst_part: jnp.ndarray, k: int):
    """Sort directed edges by (src, dst_part) and segment-sum equal keys.

    ``dst_part`` is the per-edge destination part (M,) — either gathered
    from a parts vector or maintained incrementally in a :class:`ConnState`.
    Returns ``(run_vertex, run_part, run_conn, run_valid)``, each (M,).
    Invalid runs have ``run_vertex == g.n_max`` (ghost segment).
    """
    m_max = g.m_max
    key = g.esrc.astype(jnp.uint32) * jnp.uint32(k + 1) + dst_part.astype(jnp.uint32)
    key = jnp.where(g.edge_mask(), key, _INVALID)
    order = jnp.argsort(key)
    skey = key[order]
    sw = g.adjwgt[order]
    first = jnp.concatenate([jnp.ones((1,), bool), skey[1:] != skey[:-1]])
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    run_conn = jax.ops.segment_sum(sw, run_id, num_segments=m_max)
    run_key = jnp.full((m_max,), _INVALID).at[run_id].min(skey)
    valid = run_key != _INVALID
    run_vertex = jnp.where(
        valid, (run_key // jnp.uint32(k + 1)).astype(jnp.int32), g.n_max
    )
    run_part = (run_key % jnp.uint32(k + 1)).astype(jnp.int32)
    return run_vertex, run_part, run_conn, valid


def sorted_runs(g: Graph, parts: jnp.ndarray, k: int):
    """Runs built from scratch: gather each edge's destination part."""
    return runs_from_dst_part(g, parts[g.adjncy], k)


def _seg_argmax_part(
    values: jnp.ndarray,
    part_ids: jnp.ndarray,
    seg: jnp.ndarray,
    mask: jnp.ndarray,
    n_seg: int,
    k: int,
):
    """Per-segment (max value, smallest part id attaining it). Deterministic."""
    vals = jnp.where(mask, values, 0)
    best = jax.ops.segment_max(vals, seg, num_segments=n_seg)
    best = jnp.maximum(best, 0)
    seg_c = jnp.clip(seg, 0, n_seg - 1)
    is_best = mask & (values == best[seg_c]) & (values > 0)
    cand = jnp.where(is_best, part_ids, k)  # k sorts after all real parts
    part = -jax.ops.segment_max(jnp.where(is_best, -cand, -k), seg, num_segments=n_seg)
    none = best <= 0
    return jnp.where(none, 0, best), jnp.where(none, k, part).astype(jnp.int32)


def queries_from_runs(g: Graph, runs, parts: jnp.ndarray, k: int) -> ConnQueries:
    run_vertex, run_part, run_conn, valid = runs
    n_seg = g.n_max + 1
    vclip = jnp.clip(run_vertex, 0, g.n_max - 1)
    own = valid & (run_part == parts[vclip])
    conn_self = jax.ops.segment_sum(
        jnp.where(own, run_conn, 0), run_vertex, num_segments=n_seg
    )[: g.n_max]
    alt = valid & ~own
    best_conn, best_part = _seg_argmax_part(
        run_conn, run_part, run_vertex, alt, n_seg, k
    )
    return ConnQueries(
        conn_self=conn_self.astype(jnp.int32),
        best_part=best_part[: g.n_max],
        best_conn=best_conn[: g.n_max].astype(jnp.int32),
    )


@partial(jax.jit, static_argnames=("k",))
def sorted_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    return queries_from_runs(g, sorted_runs(g, parts, k), parts, k)


def ell_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    """Pallas jet_gain kernel backend (ELL-tiled VMEM sweep).

    The TPU-native replacement for the sorted/hashtable connectivity pass —
    interpret-mode on CPU (slow; use for validation), compiled on TPU.
    """
    from repro.kernels.jet_gain.ops import csr_to_ell, jet_gain

    nbr, wgt = csr_to_ell(g)
    cs, bp, bc = jet_gain(nbr, wgt, parts, k)
    return ConnQueries(conn_self=cs, best_part=bp, best_conn=bc)


def queries(g: Graph, parts: jnp.ndarray, k: int, backend: str = "dense") -> ConnQueries:
    if backend == "dense":
        return dense_queries(g, parts, k)
    if backend == "sorted":
        return sorted_queries(g, parts, k)
    if backend == "ell":
        return ell_queries(g, parts, k)
    raise ValueError(f"unknown connectivity backend {backend!r}")


# ---------------------------------------------------------------------------
# incremental update (paper Alg 4.4)
# ---------------------------------------------------------------------------

def update_conn_matrix(mat: jnp.ndarray, g: Graph, parts_old: jnp.ndarray,
                       move: jnp.ndarray, dest: jnp.ndarray) -> jnp.ndarray:
    """Incremental connectivity update after a move list (paper Alg 4.4).

    Two edge-parallel passes: decrement every neighbor's connectivity to the
    mover's source part, increment to its destination part.  The paper falls
    back to a full rebuild beyond 10% moves; on TPU both are the same two
    scatter-adds, so the incremental form is always safe.
    """
    src_moved = move[g.esrc]
    w = jnp.where(src_moved, g.adjwgt, 0)
    p_old = parts_old[g.esrc]
    p_new = dest[g.esrc]
    mat = mat.at[g.adjncy, p_old].add(-w)
    mat = mat.at[g.adjncy, p_new].add(w)
    return mat


def update_conn_matrix_rows(mat: jnp.ndarray, g: Graph, parts_old: jnp.ndarray,
                            move: jnp.ndarray, dest: jnp.ndarray,
                            k: int) -> jnp.ndarray:
    """Alg 4.4 delta as a row-ordered one-hot sweep (the hot-path variant).

    Symmetry lets the update run source-side: "edges whose source moved
    update their destination's row" == "edges whose destination moved update
    their source's row", and source rows are CSR-contiguous.  The per-edge
    one-hot difference over k+1 columns is dense compare/multiply-accumulate
    (VPU-shaped, like the jet_gain kernel's k-sweep), and the CSR-segment
    reduction is a cumsum + boundary gather — no scatter at all, ~2x
    cheaper on CPU than the two random scatter-adds of
    :func:`update_conn_matrix`, with bit-identical output (wraparound int32
    arithmetic makes the prefix-sum difference exact).
    """
    dst_moved = move[g.adjncy]
    w = jnp.where(dst_moved, g.adjwgt, 0)
    p_old = parts_old[g.adjncy]
    p_new = dest[g.adjncy]
    cols = jnp.arange(k + 1, dtype=jnp.int32)
    diff = w[:, None] * (
        (p_new[:, None] == cols[None, :]).astype(jnp.int32)
        - (p_old[:, None] == cols[None, :]).astype(jnp.int32)
    )
    csum = jnp.concatenate(
        [jnp.zeros((1, k + 1), jnp.int32), jnp.cumsum(diff, axis=0)]
    )
    return mat + csum[g.xadj[1:]] - csum[g.xadj[:-1]]


# ---------------------------------------------------------------------------
# stateful interface — ConnState threaded through the refinement loop
# ---------------------------------------------------------------------------

class ConnState(NamedTuple):
    """Persistent per-level refinement state (paper §4.3 + Alg 4.4).

    Exactly one backend's structure is populated; the others hold zero-size
    placeholders so the pytree shape is uniform inside ``lax.while_loop``.
    ``sizes`` is delta-maintained alongside the structure; ``cut`` is
    advanced by a one-pass edge reduction over the post-move parts (the
    cheapest exact form under static shapes — see ``metrics.delta_cutsize``)
    and carried here so queries, balance checks, and best-tracking never
    recompute it.
    """

    sizes: jnp.ndarray          # (k,) int32 part weights
    cut: jnp.ndarray            # int32 scalar current cutsize
    mat: jnp.ndarray            # dense: (N, k+1) int32; else (0, 0)
    edge_dst_part: jnp.ndarray  # sorted: (M,) int32 dst part per edge; else (0,)
    ell_nbr: jnp.ndarray        # ell: (N, D) int32 neighbor ids; else (0, 0)
    ell_wgt: jnp.ndarray        # ell: (N, D) int32 edge weights; else (0, 0)
    ell_parts: jnp.ndarray      # ell: (N, D) int32 neighbor parts; else (0, 0)
    moves_applied: jnp.ndarray  # int32 move lists since last full (re)build


def _e1() -> jnp.ndarray:
    return jnp.zeros((0,), jnp.int32)


def _e2() -> jnp.ndarray:
    return jnp.zeros((0, 0), jnp.int32)


def build_state(
    g: Graph,
    parts: jnp.ndarray,
    k: int,
    backend: str = "dense",
    max_degree: int | None = None,
) -> ConnState:
    """Build the full state from a parts vector (once per level).

    ``parts`` must already map padding vertices to the ghost part ``k``.
    ``max_degree`` (ell only) must be static when tracing under jit.
    """
    from repro.core import metrics

    sizes = metrics.part_sizes(g, parts, k).astype(jnp.int32)
    cut = metrics.cutsize(g, parts).astype(jnp.int32)
    mat, edp = _e2(), _e1()
    nbr = wgt = nparts = _e2()
    if backend == "dense":
        mat = conn_matrix(g, parts, k)
    elif backend == "sorted":
        edp = jnp.where(g.edge_mask(), parts[g.adjncy], k).astype(jnp.int32)
    elif backend == "ell":
        from repro.kernels.jet_gain.ops import csr_to_ell, lookup_nbr_parts

        nbr, wgt = csr_to_ell(g, max_degree)
        nparts = lookup_nbr_parts(nbr, parts, k)
    else:
        raise ValueError(f"unknown connectivity backend {backend!r}")
    return ConnState(sizes, cut, mat, edp, nbr, wgt, nparts, jnp.int32(0))


def rebuild_state(
    g: Graph, state: ConnState, parts: jnp.ndarray, k: int, backend: str
) -> ConnState:
    """Full refresh from ``parts`` — the ``rebuild_every`` escape hatch.

    Reuses the static ELL adjacency (it never changes within a level).
    """
    from repro.core import metrics

    sizes = metrics.part_sizes(g, parts, k).astype(jnp.int32)
    cut = metrics.cutsize(g, parts).astype(jnp.int32)
    upd = {"sizes": sizes, "cut": cut, "moves_applied": jnp.int32(0)}
    if backend == "dense":
        upd["mat"] = conn_matrix(g, parts, k)
    elif backend == "sorted":
        upd["edge_dst_part"] = jnp.where(
            g.edge_mask(), parts[g.adjncy], k
        ).astype(jnp.int32)
    elif backend == "ell":
        from repro.kernels.jet_gain.ops import lookup_nbr_parts

        upd["ell_parts"] = lookup_nbr_parts(state.ell_nbr, parts, k)
    else:
        raise ValueError(f"unknown connectivity backend {backend!r}")
    return state._replace(**upd)


def apply_moves(
    g: Graph,
    state: ConnState,
    parts_old: jnp.ndarray,
    move: jnp.ndarray,
    dest: jnp.ndarray,
    k: int,
    backend: str,
) -> ConnState:
    """Advance the state past one move list (paper Alg 4.4, all backends).

    Structure updates are deltas: a scatter-free one-hot/cumsum row update
    for the dense matrix (:func:`update_conn_matrix_rows`), masked
    elementwise rewrites for the sorted / ELL structures, and a one-hot
    delta reduction for part sizes; the cut advances by a one-pass edge
    reduction.  Bit-exact against :func:`rebuild_state` (integer arithmetic
    throughout).
    """
    from repro.core import metrics

    parts_new = jnp.where(move, dest, parts_old)
    sizes = metrics.delta_part_sizes(g, state.sizes, parts_old, move, dest, k)
    cut = metrics.delta_cutsize(g, state.cut, parts_old, parts_new)
    upd = {"sizes": sizes, "cut": cut,
           "moves_applied": state.moves_applied + 1}
    if backend == "dense":
        upd["mat"] = update_conn_matrix_rows(state.mat, g, parts_old, move,
                                             dest, k)
    elif backend == "sorted":
        hit = g.edge_mask() & move[g.adjncy]
        upd["edge_dst_part"] = jnp.where(
            hit, dest[g.adjncy], state.edge_dst_part
        ).astype(jnp.int32)
    elif backend == "ell":
        from repro.kernels.jet_gain.ops import update_nbr_parts

        upd["ell_parts"] = update_nbr_parts(
            state.ell_nbr, state.ell_parts, move, dest, k
        )
    else:
        raise ValueError(f"unknown connectivity backend {backend!r}")
    return state._replace(**upd)


def state_queries(
    g: Graph, state: ConnState, parts: jnp.ndarray, k: int, backend: str
) -> ConnQueries:
    """Jetlp queries from the maintained state — no rebuild, no part gather."""
    if backend == "dense":
        return queries_from_matrix(state.mat, parts, k)
    if backend == "sorted":
        runs = runs_from_dst_part(g, state.edge_dst_part, k)
        return queries_from_runs(g, runs, parts, k)
    if backend == "ell":
        from repro.kernels.jet_gain.ops import jet_gain_from_parts

        cs, bp, bc = jet_gain_from_parts(
            state.ell_parts, state.ell_wgt, parts, k
        )
        return ConnQueries(conn_self=cs, best_part=bp, best_conn=bc)
    raise ValueError(f"unknown connectivity backend {backend!r}")


# -- valid-destination queries (Jetrw / Jetrs) from the maintained state ----

def _rw_from_matrix(mat: jnp.ndarray, valid_parts: jnp.ndarray, k: int):
    """Best valid-destination part per vertex: (best_conn, best_part, any)."""
    colmask = jnp.concatenate([valid_parts, jnp.zeros((1,), bool)])
    masked = jnp.where(colmask[None, :], mat, -1)
    best_conn = jnp.max(masked, axis=1)
    best_part = jnp.argmax(masked, axis=1).astype(jnp.int32)
    has = best_conn > 0
    return jnp.maximum(best_conn, 0), jnp.where(has, best_part, k), has


def _rw_from_runs(g: Graph, runs, valid_parts: jnp.ndarray, k: int):
    run_vertex, run_part, run_conn, valid = runs
    n_seg = g.n_max + 1
    vp = jnp.concatenate([valid_parts, jnp.zeros((1,), bool)])
    mask = valid & vp[jnp.clip(run_part, 0, k)]
    best_conn, best_part = _seg_argmax_part(
        run_conn, run_part, run_vertex, mask, n_seg, k
    )
    has = best_conn[: g.n_max] > 0
    return (
        jnp.maximum(best_conn[: g.n_max], 0),
        jnp.where(has, best_part[: g.n_max], k).astype(jnp.int32),
        has,
    )


def _rs_from_matrix(mat: jnp.ndarray, valid_parts: jnp.ndarray, k: int):
    """Sum and count of connectivity over *adjacent* valid parts per vertex."""
    colmask = jnp.concatenate([valid_parts, jnp.zeros((1,), bool)])
    sel = jnp.where(colmask[None, :], mat, 0)
    s = jnp.sum(sel, axis=1)
    cnt = jnp.sum((sel > 0).astype(jnp.int32), axis=1)
    return s, cnt


def _rs_from_runs(g: Graph, runs, valid_parts: jnp.ndarray, k: int):
    run_vertex, run_part, run_conn, valid = runs
    n_seg = g.n_max + 1
    vp = jnp.concatenate([valid_parts, jnp.zeros((1,), bool)])
    mask = valid & vp[jnp.clip(run_part, 0, k)]
    s = jax.ops.segment_sum(
        jnp.where(mask, run_conn, 0), run_vertex, num_segments=n_seg
    )[: g.n_max]
    cnt = jax.ops.segment_sum(
        jnp.where(mask & (run_conn > 0), 1, 0).astype(jnp.int32),
        run_vertex,
        num_segments=n_seg,
    )[: g.n_max]
    return s, cnt


def _state_matrix(g: Graph, state: ConnState, k: int, backend: str):
    """A dense (N, k+1) view of the state for matrix-shaped queries.

    ELL reconstructs it from the *maintained* neighbor parts — an O(N*D)
    scatter, used only on (rare) rebalance iterations.
    """
    if backend == "dense":
        return state.mat
    if backend == "ell":
        from repro.kernels.jet_gain.ops import ell_to_matrix

        return ell_to_matrix(state.ell_parts, state.ell_wgt, k)
    raise ValueError(f"unknown connectivity backend {backend!r}")


def rw_queries(
    g: Graph, state: ConnState, k: int, valid_parts: jnp.ndarray, backend: str
):
    """Jetrw: best valid-destination part from the maintained state."""
    if backend == "sorted":
        runs = runs_from_dst_part(g, state.edge_dst_part, k)
        return _rw_from_runs(g, runs, valid_parts, k)
    return _rw_from_matrix(_state_matrix(g, state, k, backend), valid_parts, k)


def rs_queries(
    g: Graph, state: ConnState, k: int, valid_parts: jnp.ndarray, backend: str
):
    """Jetrs: sum/count over valid destinations from the maintained state."""
    if backend == "sorted":
        runs = runs_from_dst_part(g, state.edge_dst_part, k)
        return _rs_from_runs(g, runs, valid_parts, k)
    return _rs_from_matrix(_state_matrix(g, state, k, backend), valid_parts, k)
