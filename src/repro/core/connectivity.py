"""Vertex-part connectivity — the Jet refinement data structure (paper §4.3).

The paper uses per-vertex GPU hashtables sized ``min(k, degree(v))``.  TPUs
have no efficient random-access atomics, so we provide two bulk array
backends behind one interface:

* ``dense``  — an (N, k+1) scatter-add connectivity matrix.  O(n*k) memory,
  fastest for small/medium k; every query is a masked row reduction.
* ``sorted`` — sorts per-edge (src, part) keys and segment-sums runs, then
  reduces runs per vertex.  O(m) memory like the paper's structure, fully
  deterministic (the paper documents hashtable-insert races as its source of
  nondeterminism; a stable sort has none).

Both backends answer the queries refinement needs (paper §4.3):
  1. conn(v, P_s(v)) and the best alternative part + its connectivity (Jetlp)
  2. best *valid-destination* part + connectivity (Jetrw)
  3. sum & count of connectivity over valid destinations (Jetrs)
  4. recompute after a move list (we recompute in O(m); the paper's
     incremental Alg 4.4 falls back to full recompute beyond 10% moves)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph


class ConnQueries(NamedTuple):
    """Per-vertex connectivity answers, all shape (N,)."""

    conn_self: jnp.ndarray   # conn(v, P_s(v))
    best_part: jnp.ndarray   # argmax_{p != P_s(v)} conn(v, p); == k if none
    best_conn: jnp.ndarray   # its connectivity (0 if none)


# ---------------------------------------------------------------------------
# dense backend
# ---------------------------------------------------------------------------

def conn_matrix(g: Graph, parts: jnp.ndarray, k: int) -> jnp.ndarray:
    """(N, k+1) connectivity matrix via scatter-add over directed edges.

    Column k is the ghost part (padding); padding edges carry weight 0 so
    they contribute nothing wherever they scatter.
    """
    dst_part = parts[g.adjncy]
    mat = jnp.zeros((g.n_max, k + 1), dtype=jnp.int32)
    return mat.at[g.esrc, dst_part].add(g.adjwgt)


def queries_from_matrix(mat: jnp.ndarray, parts: jnp.ndarray, k: int) -> ConnQueries:
    n_max = mat.shape[0]
    rows = jnp.arange(n_max, dtype=jnp.int32)
    conn_self = mat[rows, parts]
    cols = jnp.arange(k + 1, dtype=jnp.int32)
    # mask own part and the ghost column
    masked = jnp.where(
        (cols[None, :] == parts[:, None]) | (cols[None, :] == k), -1, mat
    )
    best_part = jnp.argmax(masked, axis=1).astype(jnp.int32)
    best_conn = jnp.max(masked, axis=1)
    none = best_conn <= 0  # weights positive: conn 0 means not adjacent
    best_part = jnp.where(none, k, best_part)
    best_conn = jnp.where(none, 0, best_conn)
    return ConnQueries(conn_self, best_part, best_conn)


@partial(jax.jit, static_argnames=("k",))
def dense_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    return queries_from_matrix(conn_matrix(g, parts, k), parts, k)


# ---------------------------------------------------------------------------
# sorted backend — O(m) memory
# ---------------------------------------------------------------------------

_INVALID = jnp.uint32(0xFFFFFFFF)


def sorted_runs(g: Graph, parts: jnp.ndarray, k: int):
    """Sort directed edges by (src, dst_part) and segment-sum equal keys.

    Returns ``(run_vertex, run_part, run_conn, run_valid)``, each (M,).
    Invalid runs have ``run_vertex == g.n_max`` (ghost segment).
    """
    m_max = g.m_max
    dst_part = parts[g.adjncy]
    key = g.esrc.astype(jnp.uint32) * jnp.uint32(k + 1) + dst_part.astype(jnp.uint32)
    key = jnp.where(g.edge_mask(), key, _INVALID)
    order = jnp.argsort(key)
    skey = key[order]
    sw = g.adjwgt[order]
    first = jnp.concatenate([jnp.ones((1,), bool), skey[1:] != skey[:-1]])
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    run_conn = jax.ops.segment_sum(sw, run_id, num_segments=m_max)
    run_key = jnp.full((m_max,), _INVALID).at[run_id].min(skey)
    valid = run_key != _INVALID
    run_vertex = jnp.where(
        valid, (run_key // jnp.uint32(k + 1)).astype(jnp.int32), g.n_max
    )
    run_part = (run_key % jnp.uint32(k + 1)).astype(jnp.int32)
    return run_vertex, run_part, run_conn, valid


def _seg_argmax_part(
    values: jnp.ndarray,
    part_ids: jnp.ndarray,
    seg: jnp.ndarray,
    mask: jnp.ndarray,
    n_seg: int,
    k: int,
):
    """Per-segment (max value, smallest part id attaining it). Deterministic."""
    vals = jnp.where(mask, values, 0)
    best = jax.ops.segment_max(vals, seg, num_segments=n_seg)
    best = jnp.maximum(best, 0)
    seg_c = jnp.clip(seg, 0, n_seg - 1)
    is_best = mask & (values == best[seg_c]) & (values > 0)
    cand = jnp.where(is_best, part_ids, k)  # k sorts after all real parts
    part = -jax.ops.segment_max(jnp.where(is_best, -cand, -k), seg, num_segments=n_seg)
    none = best <= 0
    return jnp.where(none, 0, best), jnp.where(none, k, part).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def sorted_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    run_vertex, run_part, run_conn, valid = sorted_runs(g, parts, k)
    n_seg = g.n_max + 1
    vclip = jnp.clip(run_vertex, 0, g.n_max - 1)
    own = valid & (run_part == parts[vclip])
    conn_self = jax.ops.segment_sum(
        jnp.where(own, run_conn, 0), run_vertex, num_segments=n_seg
    )[: g.n_max]
    alt = valid & ~own
    best_conn, best_part = _seg_argmax_part(
        run_conn, run_part, run_vertex, alt, n_seg, k
    )
    return ConnQueries(
        conn_self=conn_self.astype(jnp.int32),
        best_part=best_part[: g.n_max],
        best_conn=best_conn[: g.n_max].astype(jnp.int32),
    )


def ell_queries(g: Graph, parts: jnp.ndarray, k: int) -> ConnQueries:
    """Pallas jet_gain kernel backend (ELL-tiled VMEM sweep).

    The TPU-native replacement for the sorted/hashtable connectivity pass —
    interpret-mode on CPU (slow; use for validation), compiled on TPU.
    """
    from repro.kernels.jet_gain.ops import csr_to_ell, jet_gain

    nbr, wgt = csr_to_ell(g)
    cs, bp, bc = jet_gain(nbr, wgt, parts, k)
    return ConnQueries(conn_self=cs, best_part=bp, best_conn=bc)


def queries(g: Graph, parts: jnp.ndarray, k: int, backend: str = "dense") -> ConnQueries:
    if backend == "dense":
        return dense_queries(g, parts, k)
    if backend == "sorted":
        return sorted_queries(g, parts, k)
    if backend == "ell":
        return ell_queries(g, parts, k)
    raise ValueError(f"unknown connectivity backend {backend!r}")


# ---------------------------------------------------------------------------
# incremental update (paper Alg 4.4)
# ---------------------------------------------------------------------------

def update_conn_matrix(mat: jnp.ndarray, g: Graph, parts_old: jnp.ndarray,
                       move: jnp.ndarray, dest: jnp.ndarray) -> jnp.ndarray:
    """Incremental connectivity update after a move list (paper Alg 4.4).

    Two edge-parallel passes: decrement every neighbor's connectivity to the
    mover's source part, increment to its destination part.  The paper falls
    back to a full rebuild beyond 10% moves; on TPU both are the same two
    scatter-adds, so the incremental form is always safe.
    """
    src_moved = move[g.esrc]
    w = jnp.where(src_moved, g.adjwgt, 0)
    p_old = parts_old[g.esrc]
    p_new = dest[g.esrc]
    mat = mat.at[g.adjncy, p_old].add(-w)
    mat = mat.at[g.adjncy, p_new].add(w)
    return mat
