"""Partition quality metrics: cutsize, part sizes, imbalance, boundary."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

GHOST = -1  # sentinel meaning "use part id k for padding vertices"


def ghost_part(k: int) -> int:
    """Padding vertices live in part ``k`` (the ghost part)."""
    return k


def cutsize(g: Graph, parts: jnp.ndarray) -> jnp.ndarray:
    """Sum of weights of cut (undirected) edges. parts: (N,) int32 in [0,k]."""
    cut = jnp.where(parts[g.esrc] != parts[g.adjncy], g.adjwgt, 0)
    return jnp.sum(cut) // 2


def part_sizes(g: Graph, parts: jnp.ndarray, k: int) -> jnp.ndarray:
    """Weighted size of each part, (k,). Ghost part dropped."""
    sizes = jax.ops.segment_sum(g.vwgt, parts, num_segments=k + 1)
    return sizes[:k]


def delta_part_sizes(
    g: Graph,
    sizes: jnp.ndarray,
    parts_old: jnp.ndarray,
    move: jnp.ndarray,
    dest: jnp.ndarray,
    k: int,
) -> jnp.ndarray:
    """Part sizes after a move list, as a one-hot delta reduction.

    Dense (n, k) compare-and-sum instead of scatter — XLA lowers scatter
    per-element, so for the small k of the dense/refinement regime the
    vectorized sweep is ~5x cheaper.  Bit-exact against :func:`part_sizes`
    of the post-move parts (integer adds commute); ghost-part (k) movers
    have weight 0 by construction so they never contribute.
    """
    w = jnp.where(move, g.vwgt, 0)
    cols = jnp.arange(k, dtype=jnp.int32)
    d = jnp.sum(
        w[:, None]
        * (
            (dest[:, None] == cols[None, :]).astype(sizes.dtype)
            - (parts_old[:, None] == cols[None, :]).astype(sizes.dtype)
        ),
        axis=0,
    )
    return sizes + d


def delta_cutsize(
    g: Graph, cut: jnp.ndarray, parts_old: jnp.ndarray, parts_new: jnp.ndarray
) -> jnp.ndarray:
    """Cutsize after a move list.

    Under XLA static shapes the cheapest exact advance is a one-pass
    recompute from the post-move parts (two edge gathers + one reduction);
    the signed before/after delta form costs double the gathers for the
    same int32 result.  ``cut``/``parts_old`` are accepted for signature
    symmetry with :func:`delta_part_sizes`.
    """
    del cut, parts_old
    return cutsize(g, parts_new).astype(jnp.int32)


def size_limit(total_w: jnp.ndarray, k: int, lam: float) -> jnp.ndarray:
    """Max allowed part weight: floor((1+lam) * W / k)."""
    return jnp.floor((1.0 + lam) * total_w.astype(jnp.float32) / k).astype(jnp.int32)


def imbalance(sizes: jnp.ndarray, total_w: jnp.ndarray, k: int) -> jnp.ndarray:
    """max_p size_p * k / W - 1 (0 == perfectly balanced), float32."""
    opt = total_w.astype(jnp.float32) / k
    return jnp.max(sizes).astype(jnp.float32) / jnp.maximum(opt, 1.0) - 1.0


def is_balanced(sizes: jnp.ndarray, total_w: jnp.ndarray, k: int, lam: float) -> jnp.ndarray:
    return jnp.max(sizes) <= size_limit(total_w, k, lam)


def boundary_mask(g: Graph, parts: jnp.ndarray) -> jnp.ndarray:
    """(N,) bool — vertex has >=1 neighbor in a different part."""
    diff = (parts[g.esrc] != parts[g.adjncy]) & (g.adjwgt > 0)
    cnt = jax.ops.segment_sum(
        diff.astype(jnp.int32), g.esrc, num_segments=g.n_max
    )
    return (cnt > 0) & g.vertex_mask()
