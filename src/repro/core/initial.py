"""Initial partitioning of the coarsest graph.

The paper calls Metis on a 4-8k-vertex coarsest graph (GPU initial
partitioning is "left for future work").  Metis isn't available here, so we
provide two JAX-native methods — both get polished by a Jet refinement pass
at the coarsest level (the multilevel driver always refines level l):

* ``random``  — hash-based balanced random assignment (PuLP-style start).
* ``voronoi`` — multi-source BFS region growing from k spread-out seeds
  (graph-growing initial partitioning, Karypis-Kumar style), which gives
  connected-ish parts that refinement improves much faster.

Both methods are seeded with a *traced* int32 scalar — all hashing is
elementwise integer arithmetic, so :func:`initial_partition_batch` can vmap
one trace over a whole batch of trial seeds (DESIGN.md §9) and trial ``t``
of the batch is bit-identical to the scalar call with ``seeds[t]``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core.graph import Graph

_KNUTH = jnp.uint32(2654435761)
# Padding sort key: strictly above every real vertex key (real keys are
# hashes >> 1, so <= 0x7FFFFFFF) — a real vertex can never tie with padding.
_PAD_KEY = jnp.uint32(0xFFFFFFFF)

METHODS = ("random", "voronoi")


def _seed32(seed) -> jnp.ndarray:
    """Seed as a traced uint32 scalar (vmap-able over a trial axis)."""
    return jnp.asarray(seed).astype(jnp.uint32)


def random_partition(g: Graph, k: int, seed=0) -> jnp.ndarray:
    """Balanced random assignment: sort vertices by hash, deal round-robin.

    ``seed`` may be a Python int or a traced int32 scalar.
    """
    vid = jnp.arange(g.n_max, dtype=jnp.uint32)
    s = _seed32(seed)
    h = (vid ^ (s * jnp.uint32(7919) + jnp.uint32(13))) * _KNUTH
    h = jnp.where(g.vertex_mask(), h >> jnp.uint32(1), _PAD_KEY)
    order = jnp.argsort(h)
    rank = jnp.zeros((g.n_max,), jnp.int32).at[order].set(
        jnp.arange(g.n_max, dtype=jnp.int32)
    )
    parts = (rank % k).astype(jnp.int32)
    return jnp.where(g.vertex_mask(), parts, k)


@partial(jax.jit, static_argnames=("k",))
def _voronoi_grow(g: Graph, seeds: jnp.ndarray, k: int) -> jnp.ndarray:
    """Multi-source BFS: unassigned vertices adopt the strongest adjacent part."""
    vmask = g.vertex_mask()
    vid = jnp.arange(g.n_max, dtype=jnp.int32)
    # scatter-min keeps duplicate seeds (k > n shortfall) deterministic:
    # the smallest part id claiming a vertex wins
    parts0 = jnp.full((g.n_max,), k, jnp.int32).at[seeds].min(
        jnp.arange(k, dtype=jnp.int32)
    )
    parts0 = jnp.where(vmask, parts0, k)

    def cond(state):
        parts, changed, it = state
        return changed & (it < g.n_max)

    def body(state):
        parts, _, it = state
        # unassigned vertices: adopt the best-connected real part (cols 0..k-1)
        unassigned = (parts == k) & vmask
        mat = cn.conn_matrix(g, parts, k + 1)
        masked = mat[:, :k]
        best = jnp.argmax(masked, axis=1).astype(jnp.int32)
        has = jnp.max(masked, axis=1) > 0
        newp = jnp.where(unassigned & has, best, parts)
        changed = jnp.any(newp != parts)
        return newp, changed, it + 1

    parts, _, _ = jax.lax.while_loop(cond, body, (parts0, jnp.bool_(True), 0))
    # disconnected leftovers: deal round-robin
    left = (parts == k) & vmask
    parts = jnp.where(left, vid % k, parts)
    return parts


def spread_seeds(g: Graph, k: int, seed=0) -> jnp.ndarray:
    """k spread-out seed vertices from a seeded hash, mask-aware.

    Padding keys (:data:`_PAD_KEY`) sort strictly after every real key, so a
    padded vertex can only be picked when ``k`` exceeds the number of real
    vertices; any such shortfall is replaced round-robin over real vertex
    ids, deterministically.
    """
    vid = jnp.arange(g.n_max, dtype=jnp.uint32)
    s = _seed32(seed)
    h = (vid ^ (s * jnp.uint32(104729) + jnp.uint32(7))) * _KNUTH
    h = jnp.where(g.vertex_mask(), h >> jnp.uint32(1), _PAD_KEY)
    cand = jnp.argsort(h)[: min(k, g.n_max)].astype(jnp.int32)
    if k > g.n_max:
        # k exceeds even the padded capacity: the missing candidates are
        # forced onto the round-robin fallback below (id n_max is never < n)
        cand = jnp.concatenate([
            cand, jnp.full((k - g.n_max,), g.n_max, jnp.int32)
        ])
    fallback = jnp.arange(k, dtype=jnp.int32) % jnp.maximum(g.n, 1)
    return jnp.where(cand < g.n, cand, fallback)


def voronoi_partition(g: Graph, k: int, seed=0) -> jnp.ndarray:
    """Graph-growing from k hash-spread seeds.

    ``seed`` may be a Python int or a traced int32 scalar.
    """
    return _voronoi_grow(g, spread_seeds(g, k, seed), k)


def initial_partition(g: Graph, k: int, seed=0, method: str = "voronoi"):
    if method == "random":
        return random_partition(g, k, seed)
    if method == "voronoi":
        return voronoi_partition(g, k, seed)
    raise ValueError(f"unknown initial partition method {method!r}")


@partial(jax.jit, static_argnames=("k", "method"))
def _initial_batch(g: Graph, seeds: jnp.ndarray, k: int, method: str):
    fn = random_partition if method == "random" else voronoi_partition
    return jax.vmap(lambda s: fn(g, k, s))(seeds)


def initial_partition_batch(
    g: Graph, k: int, seeds, method: str = "voronoi"
) -> jnp.ndarray:
    """(T, n_max) int32 batch of seeded initial partitions in ONE trace.

    Row ``t`` is bit-identical to ``initial_partition(g, k, seeds[t])`` —
    the hashing is elementwise integer arithmetic and the BFS while-loop's
    batching rule freezes each trial's carry once its own condition goes
    false, so vmap changes the schedule, never the values (DESIGN.md §9).
    """
    if method not in METHODS:
        raise ValueError(f"unknown initial partition method {method!r}")
    seeds = jnp.asarray(seeds, dtype=jnp.int32)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be 1-D (one per trial), got {seeds.shape}")
    return _initial_batch(g, seeds, k, method)


@partial(jax.jit, static_argnames=("k", "method"))
def _initial_fleet(gb: Graph, seeds: jnp.ndarray, k: int, method: str):
    fn = random_partition if method == "random" else voronoi_partition
    return jax.vmap(lambda g: jax.vmap(lambda s: fn(g, k, s))(seeds))(gb)


def initial_partition_fleet(
    gb: Graph, k: int, seeds, method: str = "voronoi"
) -> jnp.ndarray:
    """(B, T, n_max) seeded initial partitions over a stacked graph batch.

    Lane ``b``, trial ``t`` is bit-identical to
    ``initial_partition(unstack_graph(gb, b), k, seeds[t])`` — the same
    §9 argument as :func:`initial_partition_batch`, lifted over the graph
    axis (all hashing is elementwise and mask-aware, so a lane's values
    never depend on its padding or on its bucket-mates).
    """
    if method not in METHODS:
        raise ValueError(f"unknown initial partition method {method!r}")
    seeds = jnp.asarray(seeds, dtype=jnp.int32)
    if seeds.ndim != 1:
        raise ValueError(f"seeds must be 1-D (one per trial), got {seeds.shape}")
    return _initial_fleet(gb, seeds, k, method)
