"""Initial partitioning of the coarsest graph.

The paper calls Metis on a 4-8k-vertex coarsest graph (GPU initial
partitioning is "left for future work").  Metis isn't available here, so we
provide two JAX-native methods — both get polished by a Jet refinement pass
at the coarsest level (the multilevel driver always refines level l):

* ``random``  — hash-based balanced random assignment (PuLP-style start).
* ``voronoi`` — multi-source BFS region growing from k spread-out seeds
  (graph-growing initial partitioning, Karypis-Kumar style), which gives
  connected-ish parts that refinement improves much faster.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core.graph import Graph


def random_partition(g: Graph, k: int, seed: int = 0) -> jnp.ndarray:
    """Balanced random assignment: sort vertices by hash, deal round-robin."""
    vid = jnp.arange(g.n_max, dtype=jnp.uint32)
    h = (vid ^ jnp.uint32(seed * 7919 + 13)) * jnp.uint32(2654435761)
    h = jnp.where(g.vertex_mask(), h >> jnp.uint32(1), jnp.uint32(0x7FFFFFFF))
    order = jnp.argsort(h)
    rank = jnp.zeros((g.n_max,), jnp.int32).at[order].set(
        jnp.arange(g.n_max, dtype=jnp.int32)
    )
    parts = (rank % k).astype(jnp.int32)
    return jnp.where(g.vertex_mask(), parts, k)


@partial(jax.jit, static_argnames=("k",))
def _voronoi_grow(g: Graph, seeds: jnp.ndarray, k: int) -> jnp.ndarray:
    """Multi-source BFS: unassigned vertices adopt the strongest adjacent part."""
    vmask = g.vertex_mask()
    vid = jnp.arange(g.n_max, dtype=jnp.int32)
    parts0 = jnp.full((g.n_max,), k, jnp.int32)
    parts0 = parts0.at[seeds].set(jnp.arange(k, dtype=jnp.int32))
    parts0 = jnp.where(vmask, parts0, k)

    def cond(state):
        parts, changed, it = state
        return changed & (it < g.n_max)

    def body(state):
        parts, _, it = state
        # unassigned vertices: adopt the best-connected real part (cols 0..k-1)
        unassigned = (parts == k) & vmask
        mat = cn.conn_matrix(g, parts, k + 1)
        masked = mat[:, :k]
        best = jnp.argmax(masked, axis=1).astype(jnp.int32)
        has = jnp.max(masked, axis=1) > 0
        newp = jnp.where(unassigned & has, best, parts)
        changed = jnp.any(newp != parts)
        return newp, changed, it + 1

    parts, _, _ = jax.lax.while_loop(cond, body, (parts0, jnp.bool_(True), 0))
    # disconnected leftovers: deal round-robin
    left = (parts == k) & vmask
    parts = jnp.where(left, vid % k, parts)
    return parts


def voronoi_partition(g: Graph, k: int, seed: int = 0) -> jnp.ndarray:
    """Graph-growing from k hash-spread seeds."""
    vid = jnp.arange(g.n_max, dtype=jnp.uint32)
    h = (vid ^ jnp.uint32(seed * 104729 + 7)) * jnp.uint32(2654435761)
    h = jnp.where(g.vertex_mask(), h >> jnp.uint32(1), jnp.uint32(0x7FFFFFFF))
    seeds = jnp.argsort(h)[:k]
    return _voronoi_grow(g, seeds, k)


def initial_partition(g: Graph, k: int, seed: int = 0, method: str = "voronoi"):
    if method == "random":
        return random_partition(g, k, seed)
    if method == "voronoi":
        return voronoi_partition(g, k, seed)
    raise ValueError(f"unknown initial partition method {method!r}")
