"""Padded CSR graph container — the core data structure of the Jet partitioner.

TPU discipline: every array has a static (padded) shape; the *true* sizes
``n`` (vertices) and ``m`` (directed edges) ride along as traced int32
scalars.  Padding vertices have weight 0 and degree 0; padding edges have
weight 0 and src/dst 0, so every weighted reduction ignores them for free.
Count-style reductions must apply :func:`edge_mask` / :func:`vertex_mask`.

The graph stores each undirected edge twice (as in CSR adjacency used by
Metis/Jet).  ``esrc[e]`` is the source vertex of directed edge ``e`` —
stored explicitly so edge-parallel kernels avoid a searchsorted per access.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


def _fit(a: jnp.ndarray, size: int, pad: str = "zeros") -> jnp.ndarray:
    """Slice or pad a 1-D array to a static length (jit-safe)."""
    cur = a.shape[0]
    if size == cur:
        return a
    if size < cur:
        return a[:size]
    if pad == "edge":
        return jnp.pad(a, (0, size - cur), mode="edge")
    return jnp.pad(a, (0, size - cur))


class Graph(NamedTuple):
    """Padded CSR graph. Shapes: xadj (N+1,), adjncy/adjwgt/esrc (M,), vwgt (N,)."""

    xadj: jnp.ndarray    # int32 (N+1,) row offsets; xadj[v+1]==xadj[v] for pads
    adjncy: jnp.ndarray  # int32 (M,) neighbor (dst) ids; 0 for padding edges
    adjwgt: jnp.ndarray  # int32 (M,) edge weights; 0 for padding edges
    vwgt: jnp.ndarray    # int32 (N,) vertex weights; 0 for padding vertices
    esrc: jnp.ndarray    # int32 (M,) source vertex of each directed edge
    n: jnp.ndarray       # int32 scalar, true vertex count (n <= N)
    m: jnp.ndarray       # int32 scalar, true directed edge count (m <= M)

    @property
    def n_max(self) -> int:
        return self.vwgt.shape[0]

    @property
    def m_max(self) -> int:
        return self.adjncy.shape[0]

    def vertex_mask(self) -> jnp.ndarray:
        return jnp.arange(self.n_max, dtype=jnp.int32) < self.n

    def edge_mask(self) -> jnp.ndarray:
        return jnp.arange(self.m_max, dtype=jnp.int32) < self.m

    def degrees(self) -> jnp.ndarray:
        return self.xadj[1:] - self.xadj[:-1]

    def total_vweight(self) -> jnp.ndarray:
        return jnp.sum(self.vwgt)

    def total_eweight(self) -> jnp.ndarray:
        """Sum of undirected edge weights (each edge stored twice)."""
        return jnp.sum(self.adjwgt) // 2

    def with_capacity(self, n_max: int, m_max: int) -> "Graph":
        """Re-bucket to new padded capacities (jit-safe).

        Requires ``n <= n_max`` and ``m <= m_max`` — padding invariants are
        preserved: the grown ``xadj`` tail repeats ``xadj[-1] == m``, and
        grown edge/vertex arrays are zero.  ``n``/``m`` stay traced.
        """
        return Graph(
            xadj=_fit(self.xadj, n_max + 1, pad="edge"),
            adjncy=_fit(self.adjncy, m_max),
            adjwgt=_fit(self.adjwgt, m_max),
            vwgt=_fit(self.vwgt, n_max),
            esrc=_fit(self.esrc, m_max),
            n=self.n,
            m=self.m,
        )


# ---------------------------------------------------------------------------
# Fleet batching — stacked graphs and shape buckets (DESIGN.md §10)
# ---------------------------------------------------------------------------

def stack_graphs(graphs: "list[Graph]") -> Graph:
    """Stack same-capacity graphs along a leading batch axis.

    The result is a plain :class:`Graph` pytree whose every leaf carries a
    leading ``(B, ...)`` axis — built for ``jax.vmap`` consumers (the fleet
    drivers).  The ``n_max`` / ``m_max`` properties read leaf ``shape[0]``
    and are therefore meaningless on a stacked graph; use the per-leaf
    shapes (``vwgt.shape == (B, N)``) or :func:`unstack_graph` instead.
    """
    if not graphs:
        raise ValueError("stack_graphs needs at least one graph")
    cap = (graphs[0].n_max, graphs[0].m_max)
    for g in graphs[1:]:
        if (g.n_max, g.m_max) != cap:
            raise ValueError(
                f"stack_graphs needs uniform capacities, got {cap} vs "
                f"{(g.n_max, g.m_max)} — re-bucket with with_capacity first"
            )
    return Graph(*(
        jnp.stack([getattr(g, f) for g in graphs]) for f in Graph._fields
    ))


def unstack_graph(gb: Graph, b: int) -> Graph:
    """Member ``b`` of a stacked graph (device-side slice, no copy)."""
    return Graph(*(leaf[b] for leaf in gb))


def bucket_graphs(
    graphs: "list[Graph]",
    ratio: float = 1.6,
    safety: float = 1.25,
    stall_ratio: float = 0.95,
    align: int = 64,
    schedule: "tuple[tuple[int, int], ...] | None" = None,
):
    """Group a fleet of graphs into static shape buckets on a shared ladder.

    Builds ONE §8 capacity ladder spanning the whole fleet (top rung =
    fleet max, aligned to ``align``) and assigns each graph the smallest
    fitting ``(n_cap, m_cap)`` rung pair, chosen per axis like
    :func:`~repro.core.coarsen.select_capacity`.  Graphs of different true
    sizes land in the same bucket whenever they round to the same rungs —
    that sharing is the whole point: one compiled executable per (bucket,
    level-rung) signature serves every member.

    With ``schedule`` given, the ladder is NOT rebuilt from the fleet max:
    assignment runs on the caller's fixed ladder, so rung pairs (and
    therefore compiled-executable signatures) stay stable across calls —
    the serving contract (DESIGN.md §11).  Every graph must fit the
    ladder's top rung; oversized graphs raise ``ValueError``.

    Returns ``(schedule, buckets)`` where ``buckets`` maps a capacity pair
    to the list of graph indices assigned to it (insertion-ordered by first
    member).  Admission is a host decision, so it costs one blocking fetch
    of all (n, m) pairs here — the last admission sync before results.
    """
    import jax

    from repro.core.coarsen import select_capacity, shape_schedule, _round_up

    if not graphs:
        raise ValueError("bucket_graphs needs at least one graph")
    sizes = [(int(n), int(m))
             for n, m in jax.device_get([(g.n, g.m) for g in graphs])]
    if schedule is None:
        n_top = _round_up(max(max(n for n, _ in sizes), 1), align)
        m_top = _round_up(max(max(m for _, m in sizes), 1), align)
        schedule = shape_schedule(n_top, m_top, ratio=ratio, safety=safety,
                                  stall_ratio=stall_ratio, align=align)
    else:
        n_top = max(nc for nc, _ in schedule)
        m_top = max(mc for _, mc in schedule)
        for i, (n, m) in enumerate(sizes):
            if n > n_top or m > m_top:
                raise ValueError(
                    f"graph {i} (n={n}, m={m}) exceeds the fixed ladder's "
                    f"top rung ({n_top}, {m_top}) — raise the ladder or "
                    "partition it standalone"
                )
    buckets: dict[tuple[int, int], list[int]] = {}
    for i, (n, m) in enumerate(sizes):
        buckets.setdefault(select_capacity(schedule, n, m), []).append(i)
    return schedule, buckets


class StackedBucket(NamedTuple):
    """One pre-stacked shape bucket, ready for ``partition_fleet_stacked``.

    ``graph`` is a stacked ``(B, ...)`` :class:`Graph` at ``capacity``;
    ``tags`` carries one caller id per lane (``None`` marks a filler lane
    — a real graph stacked only to pin the batch width, whose result the
    driver drops); ``orig_n_max`` records each lane's own padding so
    results can be restored to the caller's shape contract.
    """

    capacity: tuple
    graph: Graph
    tags: tuple
    orig_n_max: tuple


class BucketAssembler:
    """Incremental bucket assembly on a FIXED capacity ladder (§11 serving).

    ``add`` queues graphs host-side (no device work); ``flush`` performs
    ONE batched (n, m) admission fetch, assigns each graph its smallest
    fitting rung pair on the pinned ladder, re-pads members with
    :meth:`Graph.with_capacity`, and returns stacked buckets.  Unlike
    :func:`bucket_graphs`' default path — which derives the ladder from
    the fleet max, so two fleets can disagree on rungs — the ladder here
    is pinned at construction, keeping compiled-executable signatures
    stable across flushes: the whole point of warm serving.

    ``lanes`` pins every flushed bucket to a fixed batch width: buckets
    with fewer members are padded with filler copies of their first
    member (``tags`` entry ``None``), buckets with more are split into
    ``lanes``-wide chunks.  A fixed width keeps B out of the signature
    degrees of freedom — one executable per (rung, k), whatever the
    arrival pattern.  ``lanes=None`` stacks each bucket at its natural
    occupancy (the ``partition_fleet`` behavior).
    """

    def __init__(self, schedule, lanes: "int | None" = None):
        if lanes is not None and lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.schedule = tuple(schedule)
        self.lanes = lanes
        self._pending: list = []  # (tag, Graph)

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, tag, g: Graph) -> None:
        self._pending.append((tag, g))

    def flush(self) -> "list[StackedBucket]":
        if not self._pending:
            return []
        tags = [t for t, _ in self._pending]
        graphs = [g for _, g in self._pending]
        self._pending = []
        _, bucket_map = bucket_graphs(graphs, schedule=self.schedule)
        out = []
        for cap in sorted(bucket_map, reverse=True):
            idxs = bucket_map[cap]
            members = [
                g if (g.n_max, g.m_max) == cap else g.with_capacity(*cap)
                for g in (graphs[i] for i in idxs)
            ]
            width = self.lanes or len(members)
            for lo in range(0, len(members), width):
                chunk = members[lo: lo + width]
                chunk_tags = [tags[i] for i in idxs[lo: lo + width]]
                chunk_nmax = [graphs[i].n_max for i in idxs[lo: lo + width]]
                fill = width - len(chunk)
                if fill:
                    chunk = chunk + [chunk[0]] * fill
                    chunk_tags += [None] * fill
                    chunk_nmax += [cap[0]] * fill
                out.append(StackedBucket(
                    capacity=cap,
                    graph=stack_graphs(chunk),
                    tags=tuple(chunk_tags),
                    orig_n_max=tuple(chunk_nmax),
                ))
        return out


def csr_from_edge_runs(
    cu: jnp.ndarray,
    cv: jnp.ndarray,
    w: jnp.ndarray,
    valid: jnp.ndarray,
    n_edges: jnp.ndarray,
    vwgt: jnp.ndarray,
    n_vertices: jnp.ndarray,
    *,
    n_max: int,
    m_max: int,
) -> Graph:
    """Device-side CSR constructor from deduplicated edge runs (jit-safe).

    ``cu``/``cv``/``w`` are edge runs sorted lexicographically by (cu, cv)
    with all valid runs contiguous at the front (``valid`` marks them);
    ``n_edges``/``n_vertices`` are traced true counts.  Builds ``xadj`` by
    segment-count + cumsum entirely on device — no host repack.
    """
    counts = jnp.zeros(n_max, dtype=jnp.int32).at[
        jnp.where(valid, cu, 0)
    ].add(valid.astype(jnp.int32), mode="drop")
    xadj = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
    )
    return Graph(
        xadj=xadj,
        adjncy=_fit(jnp.where(valid, cv, 0).astype(jnp.int32), m_max),
        adjwgt=_fit(jnp.where(valid, w, 0).astype(jnp.int32), m_max),
        vwgt=_fit(vwgt.astype(jnp.int32), n_max),
        esrc=_fit(jnp.where(valid, cu, 0).astype(jnp.int32), m_max),
        n=n_vertices.astype(jnp.int32),
        m=n_edges.astype(jnp.int32),
    )


def build_csr_host(
    n: int,
    edges: np.ndarray,
    eweights: np.ndarray | None = None,
    vweights: np.ndarray | None = None,
    n_max: int | None = None,
    m_max: int | None = None,
) -> Graph:
    """Host-side CSR builder from an undirected edge list (u, v) pairs.

    Removes self loops, deduplicates parallel edges (summing weights), and
    symmetrizes.  ``edges`` is (E, 2) int; weights default to 1.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if eweights is None:
        eweights = np.ones(edges.shape[0], dtype=np.int64)
    else:
        eweights = np.asarray(eweights, dtype=np.int64)
    # Drop self loops.
    keep = edges[:, 0] != edges[:, 1]
    edges, eweights = edges[keep], eweights[keep]
    # Canonicalize + dedup (sum weights of parallel edges).
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    key = lo * n + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, eweights = key[order], lo[order], hi[order], eweights[order]
    uniq, inv = np.unique(key, return_inverse=True)
    w = np.zeros(uniq.shape[0], dtype=np.int64)
    np.add.at(w, inv, eweights)
    lo = (uniq // n).astype(np.int64)
    hi = (uniq % n).astype(np.int64)
    # Symmetrize.
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    ew = np.concatenate([w, w])
    order = np.argsort(src * n + dst, kind="stable")
    src, dst, ew = src[order], dst[order], ew[order]
    m = src.shape[0]
    xadj = np.zeros(n + 1, dtype=np.int64)
    np.add.at(xadj, src + 1, 1)
    xadj = np.cumsum(xadj)
    if vweights is None:
        vweights = np.ones(n, dtype=np.int64)
    else:
        vweights = np.asarray(vweights, dtype=np.int64)

    n_max = int(n_max) if n_max is not None else int(n)
    m_max = int(m_max) if m_max is not None else int(m)
    assert n_max >= n and m_max >= m, (n_max, n, m_max, m)

    xadj_p = np.full(n_max + 1, m, dtype=np.int32)
    xadj_p[: n + 1] = xadj
    adjncy_p = np.zeros(m_max, dtype=np.int32)
    adjncy_p[:m] = dst
    adjwgt_p = np.zeros(m_max, dtype=np.int32)
    adjwgt_p[:m] = ew
    vwgt_p = np.zeros(n_max, dtype=np.int32)
    vwgt_p[:n] = vweights
    esrc_p = np.zeros(m_max, dtype=np.int32)
    esrc_p[:m] = src
    return Graph(
        xadj=jnp.asarray(xadj_p),
        adjncy=jnp.asarray(adjncy_p),
        adjwgt=jnp.asarray(adjwgt_p),
        vwgt=jnp.asarray(vwgt_p),
        esrc=jnp.asarray(esrc_p),
        n=jnp.asarray(n, dtype=jnp.int32),
        m=jnp.asarray(m, dtype=jnp.int32),
    )


def graph_to_host(g: Graph) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Return (n, edges(u<v), eweights, vweights) on host, unpadded."""
    n = int(g.n)
    m = int(g.m)
    src = np.asarray(g.esrc)[:m]
    dst = np.asarray(g.adjncy)[:m]
    w = np.asarray(g.adjwgt)[:m]
    keep = src < dst
    return n, np.stack([src[keep], dst[keep]], axis=1), w[keep], np.asarray(g.vwgt)[:n]


def validate_host(g: Graph) -> None:
    """Structural invariants — host-side, for tests."""
    n, m = int(g.n), int(g.m)
    xadj = np.asarray(g.xadj)
    adjncy = np.asarray(g.adjncy)
    adjwgt = np.asarray(g.adjwgt)
    esrc = np.asarray(g.esrc)
    assert xadj[0] == 0 and xadj[n] == m
    assert np.all(np.diff(xadj[: n + 1]) >= 0)
    assert np.all(xadj[n:] == m)
    assert np.all(adjncy[:m] >= 0) and np.all(adjncy[:m] < n)
    assert np.all(adjwgt[:m] > 0)
    assert np.all(adjwgt[m:] == 0)
    # esrc consistent with xadj
    expect_src = np.repeat(np.arange(n), np.diff(xadj[: n + 1]))
    assert np.array_equal(esrc[:m], expect_src)
    # no self loops
    assert np.all(adjncy[:m] != esrc[:m])
    # symmetric with equal weights
    fwd = {}
    for e in range(m):
        fwd[(int(esrc[e]), int(adjncy[e]))] = int(adjwgt[e])
    for (u, v), w in fwd.items():
        assert fwd.get((v, u)) == w, f"asymmetric edge {(u, v)}"
