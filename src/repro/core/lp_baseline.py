"""LP refinement variants for the Table 3 ablation + a size-constrained LP
competitor (the refinement family of Mt-Metis/KaMinPar that the paper groups
as "Label Propagation", §2.5.1).

Variant matrix (paper §7.1.4):
  baseline : X = {F >= 0}; commit all of X; no locks
  locks    : baseline + lock bit
  weak_ab  : X = {F >= 0}; afterburner second filter
  full_ab  : X per Eq 4.3 (negative gains admitted); afterburner
  full     : full_ab + locks   (== Jetlp)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core import metrics
from repro.core.graph import Graph

from repro.core.refine import VARIANTS, jetlp_moves, variant_flags  # noqa: F401  (re-export)


@partial(jax.jit, static_argnames=("k", "lam", "iters"))
def constrained_lp_refine(
    g: Graph,
    parts0: jnp.ndarray,
    k: int,
    lam: float = 0.03,
    iters: int = 24,
):
    """Size-constrained synchronous LP (the classic competitor, §2.5.1).

    Each iteration: every boundary vertex proposes its best positive-gain
    move; proposals are admitted per destination part up to the part's
    remaining headroom (gain-descending, via a (dest, -gain) sorted prefix
    scan) so the balance constraint is never violated.  Keeps the best seen.
    """
    W = g.total_vweight()
    limit = metrics.size_limit(W, k, lam)
    vmask = g.vertex_mask()
    parts0 = jnp.where(vmask, parts0, k).astype(jnp.int32)
    n_max = g.n_max
    GAIN_CAP = jnp.int32(1 << 20)

    def body(carry, _):
        parts, best_parts, best_cost = carry
        q = cn.dense_queries(g, parts, k)
        F = q.best_conn - q.conn_self
        want = vmask & (q.best_conn > 0) & (F > 0)
        dest = jnp.where(want, q.best_part, k)
        # admit by descending gain within each destination, up to headroom
        gain_c = jnp.clip(F, -GAIN_CAP + 1, GAIN_CAP - 1)
        key = jnp.where(want, dest * (2 * GAIN_CAP) + (GAIN_CAP - gain_c),
                        jnp.int32(2147483647))
        order = jnp.argsort(key)
        want_s = want[order]
        dseg = jnp.where(want_s, dest[order], k)
        w_s = jnp.where(want_s, g.vwgt[order], 0)
        cum = jnp.cumsum(w_s)
        cum_b = cum - w_s
        first = jnp.concatenate([jnp.ones((1,), bool), dseg[1:] != dseg[:-1]])
        off = jnp.zeros((k + 1,), jnp.int32).at[dseg].max(
            jnp.where(first, cum_b, 0)
        )
        within = cum_b - off[dseg]
        sizes = metrics.part_sizes(g, parts, k)
        headroom = jnp.maximum(limit - sizes, 0)
        admit_s = want_s & (within < headroom[jnp.clip(dseg, 0, k - 1)])
        admit = jnp.zeros((n_max,), bool).at[order].set(admit_s)
        parts2 = jnp.where(admit, dest, parts)
        cost2 = metrics.cutsize(g, parts2).astype(jnp.int32)
        sizes2 = metrics.part_sizes(g, parts2, k)
        ok2 = jnp.max(sizes2) <= limit
        take = ok2 & (cost2 < best_cost)
        return (
            parts2,
            jnp.where(take, parts2, best_parts),
            jnp.where(take, cost2, best_cost),
        ), None

    cost0 = metrics.cutsize(g, parts0).astype(jnp.int32)
    sizes0 = metrics.part_sizes(g, parts0, k)
    bal0 = jnp.max(sizes0) <= limit
    best0 = jnp.where(bal0, cost0, jnp.int32(2147483647))
    (parts, best_parts, best_cost), _ = jax.lax.scan(
        body, (parts0, parts0, best0), None, length=iters
    )
    return best_parts, {"best_cost": best_cost}
