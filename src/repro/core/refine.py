"""Jet refinement — Jetlp (Alg 4.2) and the outer driver (Alg 4.1).

Everything here is one jittable ``lax.while_loop`` per level: the paper's
bulk-synchronous design maps 1:1 onto XLA.  The three iteration kinds
(Jetlp / weak rebalance / strong rebalance) are ``lax.cond`` branches chosen
by the balance state, exactly as Alg 4.1 alternates them.

Stateful incremental refinement (DESIGN.md §3): a :class:`~repro.core.
connectivity.ConnState` — connectivity structure, part sizes, and cutsize —
is built once per level, threaded through :class:`RefineState` inside the
loop, and advanced after every move list with Alg 4.4 delta updates.  The
loop body therefore never rebuilds connectivity or recomputes sizes/cut
from the parts vector on the default path; ``rebuild_every`` is the
periodic-full-rebuild escape hatch (1 == the paper's always-rebuild
fallback, 0 == never).  All three iteration kinds consume the same
``ConnQueries`` computed once per iteration from the threaded state.

Deviations from the paper are documented in DESIGN.md §6; the functional
behaviour (filters, afterburner ordering, locking, best-partition tracking
with the phi tolerance) follows the paper line by line.

Batch polymorphism (DESIGN.md §§9-10): ``_refine_loop`` (and everything it
calls — ``jetlp_moves``, the rebalance kernels, the ConnState interface) is
vmappable over a leading trial axis, and over a further graph axis for the
fleet path.  Traced stats stay traced; the loop condition is per-trial, and
JAX's ``while_loop`` batching rule freezes a trial's carry once its own
condition goes false, so a vmapped trial walks the exact trajectory of its
sequential run — the batch merely runs until the LAST trial's patience
expires.  The optional ``active`` flag extends the same mechanism to whole
lanes: a fleet lane whose own hierarchy ends above the current level enters
with ``active=False``, its condition is false at iteration 0, and its
(identity-projected) partition passes through bit-untouched.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import connectivity as cn
from repro.core import metrics
from repro.core import rebalance as rb
from repro.core.graph import Graph


VARIANTS = ("baseline", "locks", "weak_ab", "full_ab", "full")


def variant_flags(variant: str):
    """(use_ratio_filter, use_afterburner, use_locks) — Table 3 ablations."""
    return {
        "baseline": (False, False, False),
        "locks": (False, False, True),
        "weak_ab": (False, True, False),
        "full_ab": (True, True, False),
        "full": (True, True, True),
    }[variant]


def jetlp_moves(
    g: Graph,
    parts: jnp.ndarray,
    k: int,
    lock: jnp.ndarray,
    c: float,
    backend: str = "dense",
    variant: str = "full",
    queries: cn.ConnQueries | None = None,
):
    """One unconstrained LP pass (Alg 4.2). Returns (move_mask, dest).

    First filter: Eq 4.3 ``-F(v) < floor(c * conn(v, P_s))  or  F(v) >= 0``.
    Second filter (afterburner): recompute gain against the approximate next
    state merged under ``ord`` (Eq 4.1), keep non-negative.  ``variant``
    selects the paper's §7.1.4 ablations (see ``variant_flags``).

    ``queries`` is the shared per-iteration ConnQueries from the threaded
    state; standalone callers may omit it and pay for a one-off build.
    """
    use_ratio, use_ab, use_locks = variant_flags(variant)
    vmask = g.vertex_mask()
    q = queries if queries is not None else cn.queries(g, parts, k,
                                                       backend=backend)
    F = q.best_conn - q.conn_self  # gain of the best single move
    boundary = q.best_conn > 0

    if use_ratio:
        thr = jnp.floor(c * q.conn_self.astype(jnp.float32)).astype(jnp.int32)
        filter1 = (F >= 0) | (-F < thr)  # Eq 4.3 (strict <, floor rounding)
    else:
        filter1 = F >= 0
    X = vmask & boundary & filter1
    if use_locks:
        X = X & ~lock
    Pd = jnp.where(X, q.best_part, parts)
    if not use_ab:
        return X, Pd

    # Afterburner: per-edge approximate next state.
    u, v, w = g.adjncy, g.esrc, g.adjwgt
    Fu = F[u]
    Fv = F[v]
    # ord(u) < ord(v): u moves "first" iff higher priority gain, tie -> smaller id
    u_first = X[u] & ((Fu > Fv) | ((Fu == Fv) & (u < v)))
    pu = jnp.where(u_first, Pd[u], parts[u])
    contrib = w * (
        (pu == Pd[v]).astype(jnp.int32) - (pu == parts[v]).astype(jnp.int32)
    )
    F2 = jax.ops.segment_sum(
        jnp.where(g.edge_mask() & X[v], contrib, 0), v, num_segments=g.n_max
    )
    move = X & (F2 >= 0)
    return move, Pd


class RefineState(NamedTuple):
    parts: jnp.ndarray
    conn: cn.ConnState           # threaded connectivity/sizes/cut state
    best_parts: jnp.ndarray
    best_cost: jnp.ndarray       # int32 cutsize of best
    best_maxsize: jnp.ndarray    # int32 max part weight of best
    best_balanced: jnp.ndarray   # bool
    lock: jnp.ndarray            # bool (N,) — last Jetlp move set
    since_best: jnp.ndarray      # int32 iterations since best improved
    weak_count: jnp.ndarray      # int32 consecutive weak rebalances
    it: jnp.ndarray              # int32 total iterations
    lp_iters: jnp.ndarray        # int32 (stats)
    rb_iters: jnp.ndarray        # int32 (stats)


def jet_refine(
    g: Graph,
    parts0: jnp.ndarray,
    k: int,
    lam: float = 0.03,
    c: float = 0.75,
    phi: float = 0.999,
    backend: str = "dense",
    patience: int = 12,
    max_iter: int = 200,
    b_max: int = 2,
    variant: str = "full",
    rebuild_every: int = 0,
    conn0: cn.ConnState | None = None,
    max_degree: int | None = None,
):
    """Alg 4.1. Returns (best_parts, stats dict).

    Host-side wrapper: normalizes the input partition, builds the per-level
    ConnState (unless the caller — e.g. the multilevel driver — already owns
    one), resolves the static ELL width, then enters the jitted loop.
    """
    if rebuild_every < 0:
        raise ValueError(f"rebuild_every must be >= 0, got {rebuild_every}")
    parts0 = jnp.where(
        g.vertex_mask(), jnp.asarray(parts0).astype(jnp.int32), k
    )
    if conn0 is None:
        if backend == "ell" and max_degree is None:
            max_degree = int(jax.device_get(jnp.max(g.degrees())))
        conn0 = cn.build_state(g, parts0, k, backend, max_degree=max_degree)
    return _refine_loop(
        g, parts0, conn0, phi,
        k=k, lam=lam, c=c, backend=backend, patience=patience,
        max_iter=max_iter, b_max=b_max, variant=variant,
        rebuild_every=rebuild_every,
    )


@partial(
    jax.jit,
    static_argnames=(
        "k", "lam", "c", "backend", "patience", "max_iter", "b_max",
        "variant", "rebuild_every",
    ),
)
def _refine_loop(
    g: Graph,
    parts0: jnp.ndarray,
    conn0: cn.ConnState,
    phi,
    *,
    k: int,
    lam: float,
    c: float,
    backend: str,
    patience: int,
    max_iter: int,
    b_max: int,
    variant: str,
    rebuild_every: int,
    active=None,
):
    W = g.total_vweight()
    limit = metrics.size_limit(W, k, lam)

    cost0 = conn0.cut
    max0 = jnp.max(conn0.sizes).astype(jnp.int32)
    st = RefineState(
        parts=parts0,
        conn=conn0,
        best_parts=parts0,
        best_cost=cost0,
        best_maxsize=max0,
        best_balanced=max0 <= limit,
        lock=jnp.zeros((g.n_max,), bool),
        since_best=jnp.int32(0),
        weak_count=jnp.int32(0),
        it=jnp.int32(0),
        lp_iters=jnp.int32(0),
        rb_iters=jnp.int32(0),
    )

    def cond(st: RefineState):
        ok = (st.since_best < patience) & (st.it < max_iter)
        if active is not None:
            # fleet lane masking (DESIGN.md §10): an inactive lane's loop
            # condition is false from iteration 0, so the while_loop batching
            # rule freezes its carry immediately and the lane's best_parts
            # pass the (projected) input partition through untouched
            ok = ok & active
        return ok

    def body(st: RefineState):
        balanced = jnp.max(st.conn.sizes) <= limit
        # one ConnQueries per iteration, shared by all three move kinds
        q = cn.state_queries(g, st.conn, st.parts, k, backend)

        def do_lp(_):
            move, dest = jetlp_moves(
                g, st.parts, k, st.lock, c, backend, variant, queries=q
            )
            return move, dest, move, jnp.int32(0), jnp.int32(1), jnp.int32(0)

        def do_rb(_):
            def weak(_):
                return rb.jetrw_moves(g, st.parts, k, lam, backend,
                                      conn=st.conn, queries=q)

            def strong(_):
                return rb.jetrs_moves(g, st.parts, k, lam, backend,
                                      conn=st.conn, queries=q)

            move, dest = jax.lax.cond(st.weak_count < b_max, weak, strong,
                                      None)
            # rebalancing does not touch lock state (paper §4.1.3)
            return (move, dest, st.lock, st.weak_count + 1, jnp.int32(0),
                    jnp.int32(1))

        move, dest, lock2, weak2, dlp, drb = jax.lax.cond(
            balanced, do_lp, do_rb, None
        )
        parts2 = jnp.where(move, dest, st.parts)

        # Alg 4.4 delta update; `rebuild_every` is the full-rebuild hatch.
        def incr(_):
            return cn.apply_moves(g, st.conn, st.parts, move, dest, k,
                                  backend)

        def full(_):
            return cn.rebuild_state(g, st.conn, parts2, k, backend)

        if rebuild_every == 1:
            conn2 = full(None)
        elif rebuild_every == 0:
            conn2 = incr(None)
        else:
            conn2 = jax.lax.cond(
                (st.it + 1) % rebuild_every == 0, full, incr, None
            )

        cost2 = conn2.cut
        max2 = jnp.max(conn2.sizes).astype(jnp.int32)
        bal2 = max2 <= limit

        # Best tracking (Alg 4.1 lines 16-23, fixed so a balanced partition
        # always supersedes an unbalanced best — see DESIGN.md §6).
        take_bal = bal2 & (~st.best_balanced | (cost2 < st.best_cost))
        significant = bal2 & (
            ~st.best_balanced
            | (cost2.astype(jnp.float32) < phi * st.best_cost.astype(jnp.float32))
        )
        take_imb = (~bal2) & (~st.best_balanced) & (max2 < st.best_maxsize)
        take = take_bal | take_imb
        reset = significant | take_imb

        return RefineState(
            parts=parts2,
            conn=conn2,
            best_parts=jnp.where(take, parts2, st.best_parts),
            best_cost=jnp.where(take, cost2, st.best_cost),
            best_maxsize=jnp.where(take, max2, st.best_maxsize),
            best_balanced=st.best_balanced | bal2,
            lock=lock2,
            since_best=jnp.where(reset, jnp.int32(0), st.since_best + 1),
            weak_count=jnp.where(bal2, jnp.int32(0), weak2),
            it=st.it + 1,
            lp_iters=st.lp_iters + dlp,
            rb_iters=st.rb_iters + drb,
        )

    st = jax.lax.while_loop(cond, body, st)
    stats = {
        "iterations": st.it,
        "lp_iters": st.lp_iters,
        "rb_iters": st.rb_iters,
        "best_cost": st.best_cost,
        "best_maxsize": st.best_maxsize,
        "best_balanced": st.best_balanced,
    }
    return st.best_parts, stats
