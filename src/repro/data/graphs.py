"""Synthetic graph generators mirroring the paper's test-set classes.

The paper's suite (§5.2) spans meshes (grid/cube), finite-element-like
graphs, social networks, and web crawls.  We generate laptop-scale members
of each class: 2D/3D lattices (the paper's `grid`/`cube`), RMAT power-law
graphs (social/web-like), Watts-Strogatz small-world rings, and random
geometric graphs (finite-element-like).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, build_csr_host


def grid2d(rows: int, cols: int, **kw) -> Graph:
    """The paper's `grid` class: 2D lattice, diameter O(sqrt(n))."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    return build_csr_host(rows * cols, np.concatenate(e), **kw)


def grid3d(nx: int, ny: int, nz: int, **kw) -> Graph:
    """The paper's `cube` class: 3D lattice, diameter O(n^(1/3))."""
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    e = []
    e.append(np.stack([idx[:, :, :-1].ravel(), idx[:, :, 1:].ravel()], 1))
    e.append(np.stack([idx[:, :-1, :].ravel(), idx[:, 1:, :].ravel()], 1))
    e.append(np.stack([idx[:-1, :, :].ravel(), idx[1:, :, :].ravel()], 1))
    return build_csr_host(nx * ny * nz, np.concatenate(e), **kw)


def rmat(scale: int, edge_factor: int = 8, a=0.57, b=0.19, c=0.19, seed: int = 0,
         **kw) -> Graph:
    """RMAT power-law generator (Graph500 parameters) — social/web-like."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    ne = n * edge_factor
    src = np.zeros(ne, dtype=np.int64)
    dst = np.zeros(ne, dtype=np.int64)
    for lvl in range(scale):
        r = rng.random(ne)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(ne)
        thresh = np.where(src_bit == 0, a / (a + b), c / (1.0 - a - b))
        dst_bit = (r2 >= thresh).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    edges = np.stack([src, dst], 1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    # Relabel to the largest connected component is overkill for tests;
    # just drop isolated vertices by compacting ids.
    used = np.unique(edges)
    remap = -np.ones(n, dtype=np.int64)
    remap[used] = np.arange(used.shape[0])
    edges = remap[edges]
    return build_csr_host(used.shape[0], edges, **kw)


def small_world(n: int, k_ring: int = 4, beta: float = 0.1, seed: int = 0,
                **kw) -> Graph:
    """Watts-Strogatz ring with rewiring — small diameter, regular-ish."""
    rng = np.random.default_rng(seed)
    base = np.arange(n)
    e = []
    for off in range(1, k_ring // 2 + 1):
        dst = (base + off) % n
        rewire = rng.random(n) < beta
        dst = np.where(rewire, rng.integers(0, n, n), dst)
        e.append(np.stack([base, dst], 1))
    edges = np.concatenate(e)
    edges = edges[edges[:, 0] != edges[:, 1]]
    return build_csr_host(n, edges, **kw)


def random_geometric(n: int, radius: float | None = None, seed: int = 0,
                     **kw) -> Graph:
    """Random geometric graph in the unit square — FEM-mesh-like."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    if radius is None:
        radius = 1.8 / np.sqrt(n)
    # grid-bucketed neighbor search
    cell = radius
    gx = (pts[:, 0] // cell).astype(np.int64)
    gy = (pts[:, 1] // cell).astype(np.int64)
    ncell = int(np.ceil(1.0 / cell)) + 1
    cell_id = gx * ncell + gy
    order = np.argsort(cell_id, kind="stable")
    edges = []
    from collections import defaultdict

    buckets = defaultdict(list)
    for i in order:
        buckets[cell_id[i]].append(i)
    for i in range(n):
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                cid = (gx[i] + dx) * ncell + (gy[i] + dy)
                for j in buckets.get(cid, ()):  # noqa: B023
                    if j > i and np.sum((pts[i] - pts[j]) ** 2) < radius**2:
                        edges.append((i, j))
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # keep only the giant component's vertices connected via compaction
    g = build_csr_host(n, edges, **kw)
    return g


def star(n: int, **kw) -> Graph:
    edges = np.stack([np.zeros(n - 1, dtype=np.int64), np.arange(1, n)], 1)
    return build_csr_host(n, edges, **kw)


def complete(n: int, **kw) -> Graph:
    i, j = np.triu_indices(n, 1)
    return build_csr_host(n, np.stack([i, j], 1), **kw)


SUITE = {
    # name: (factory, kwargs, paper class)
    "grid_64x32": (grid2d, dict(rows=64, cols=32), "artificial mesh (2D)"),
    "cube_12": (grid3d, dict(nx=12, ny=12, nz=12), "artificial mesh (3D)"),
    "rmat_12": (rmat, dict(scale=12, edge_factor=8), "social/web"),
    "smallworld_4k": (small_world, dict(n=4096, k_ring=6), "complex network"),
    "geo_4k": (random_geometric, dict(n=4096), "finite element"),
}


def suite_graph(name: str) -> Graph:
    fac, kw, _ = SUITE[name]
    return fac(**kw)
