"""Synthetic data pipelines for every arch family (host-side numpy, sharded
consumption via launch/train.py).  Includes the GraphSAGE neighbor sampler
(fanout sampling is part of the system per the assignment).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch


# ---------------------------------------------------------------------------
# LM tokens
# ---------------------------------------------------------------------------

def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Infinite stream of (tokens, labels) — Zipf-ish synthetic LM data."""
    rng = np.random.default_rng(seed)
    while True:
        probs = 1.0 / np.arange(1, vocab + 1)
        probs /= probs.sum()
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield {
            "tokens": jnp.asarray(toks[:, :-1].astype(np.int32)),
            "labels": jnp.asarray(toks[:, 1:].astype(np.int32)),
        }


# ---------------------------------------------------------------------------
# molecules / meshes (SchNet, NequIP, MeshGraphNet)
# ---------------------------------------------------------------------------

def _radius_edges(pos, cutoff, max_edges):
    n = pos.shape[0]
    d2 = np.sum((pos[:, None] - pos[None, :]) ** 2, -1)
    src, dst = np.nonzero((d2 < cutoff**2) & ~np.eye(n, dtype=bool))
    if src.shape[0] > max_edges:
        src, dst = src[:max_edges], dst[:max_edges]
    return src, dst


def molecule_batch(n_graphs: int, atoms: int = 30, n_species: int = 10,
                   cutoff: float = 3.0, edges_per_graph: int = 512,
                   seed: int = 0, energy_rule: str = "pairs"):
    """Batched small molecules. Energy label = #close pairs (learnable)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * atoms
    E = n_graphs * edges_per_graph
    feats = np.zeros((N, 1), np.float32)
    pos = np.zeros((N, 3), np.float32)
    gid = np.repeat(np.arange(n_graphs), atoms).astype(np.int32)
    senders = np.full(E, N, np.int32)
    receivers = np.full(E, N, np.int32)
    energy = np.zeros(n_graphs, np.float32)
    e_at = 0
    for g in range(n_graphs):
        p = rng.random((atoms, 3)).astype(np.float32) * 3.0
        z = rng.integers(1, n_species, atoms)
        s, d = _radius_edges(p, cutoff, edges_per_graph)
        base = g * atoms
        m = min(s.shape[0], edges_per_graph)
        senders[e_at:e_at + m] = base + s[:m]
        receivers[e_at:e_at + m] = base + d[:m]
        e_at += edges_per_graph
        feats[base:base + atoms, 0] = z
        pos[base:base + atoms] = p
        energy[g] = 0.05 * m + 0.1 * z.sum()
    batch = GraphBatch(
        node_feat=jnp.asarray(feats),
        senders=jnp.asarray(senders),
        receivers=jnp.asarray(receivers),
        edge_feat=None,
        pos=jnp.asarray(pos),
        graph_id=jnp.asarray(gid),
        n_graphs=n_graphs,
    )
    return {"graph": batch, "energy": jnp.asarray(energy)}


def mesh_batch(nx: int = 16, ny: int = 16, seed: int = 0):
    """A 2D triangulated grid mesh with a synthetic smooth target field."""
    rng = np.random.default_rng(seed)
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1))
    edges = np.concatenate(e)
    edges = np.concatenate([edges, edges[:, ::-1]])  # both directions
    pos3 = np.zeros((n, 3), np.float32)
    pos3[:, 0] = (np.arange(n) // ny) / nx
    pos3[:, 1] = (np.arange(n) % ny) / ny
    feats = rng.standard_normal((n, 4)).astype(np.float32)
    target = np.stack(
        [np.sin(3 * pos3[:, 0]) * np.cos(2 * pos3[:, 1]),
         np.cos(4 * pos3[:, 0] * pos3[:, 1])], -1
    ).astype(np.float32)
    batch = GraphBatch(
        node_feat=jnp.asarray(feats),
        senders=jnp.asarray(edges[:, 0].astype(np.int32)),
        receivers=jnp.asarray(edges[:, 1].astype(np.int32)),
        edge_feat=None,
        pos=jnp.asarray(pos3),
        graph_id=jnp.zeros((n,), jnp.int32),
        n_graphs=1,
    )
    return {"graph": batch, "target": jnp.asarray(target)}


# ---------------------------------------------------------------------------
# node classification + neighbor sampler (GraphSAGE)
# ---------------------------------------------------------------------------

def community_graph(n: int = 1000, n_classes: int = 8, d_feat: int = 64,
                    p_in: float = 0.02, p_out: float = 0.001, seed: int = 0):
    """SBM-style labeled graph (host CSR) for node classification."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n)
    blocks = labels[:, None] == labels[None, :]
    probs = np.where(blocks, p_in, p_out)
    adj = rng.random((n, n)) < probs
    adj = np.triu(adj, 1)
    src, dst = np.nonzero(adj)
    edges = np.concatenate(
        [np.stack([src, dst], 1), np.stack([dst, src], 1)])
    feats = (np.eye(n_classes)[labels] @ rng.standard_normal(
        (n_classes, d_feat)) + 0.5 * rng.standard_normal((n, d_feat))
             ).astype(np.float32)
    return edges.astype(np.int64), feats, labels.astype(np.int32)


class NeighborSampler:
    """GraphSAGE fanout sampler: k-hop sampled subgraph batches (numpy)."""

    def __init__(self, edges: np.ndarray, n: int, fanouts=(15, 10), seed=0):
        self.n = n
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)
        order = np.argsort(edges[:, 1], kind="stable")  # CSC by dst
        self.sorted_src = edges[order, 0]
        self.offsets = np.zeros(n + 1, np.int64)
        np.add.at(self.offsets, edges[:, 1] + 1, 1)
        self.offsets = np.cumsum(self.offsets)

    def _sample_neighbors(self, nodes, fanout):
        src_list, dst_list = [], []
        for v in nodes:
            lo, hi = self.offsets[v], self.offsets[v + 1]
            if hi == lo:
                continue
            take = min(fanout, hi - lo)
            sel = self.rng.choice(hi - lo, take, replace=False) + lo
            src_list.append(self.sorted_src[sel])
            dst_list.append(np.full(take, v))
        if not src_list:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(src_list), np.concatenate(dst_list)

    def sample(self, seeds: np.ndarray, feats: np.ndarray,
               labels: np.ndarray, pad_nodes: int, pad_edges: int):
        """Returns a padded GraphBatch over the union of sampled nodes with
        labels only on the seed nodes (-1 elsewhere)."""
        nodes = list(seeds)
        node_set = set(seeds.tolist())
        all_src, all_dst = [], []
        frontier = seeds
        for fanout in self.fanouts:
            s, d = self._sample_neighbors(frontier, fanout)
            all_src.append(s)
            all_dst.append(d)
            new = [v for v in np.unique(s) if v not in node_set]
            node_set.update(new)
            nodes.extend(new)
            frontier = np.asarray(new, dtype=np.int64)
            if frontier.size == 0:
                break
        nodes = np.asarray(nodes[:pad_nodes], dtype=np.int64)
        remap = {int(v): i for i, v in enumerate(nodes)}
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
        keep = [i for i in range(src.shape[0])
                if int(src[i]) in remap and int(dst[i]) in remap]
        keep = keep[:pad_edges]
        e_src = np.full(pad_edges, pad_nodes, np.int32)
        e_dst = np.full(pad_edges, pad_nodes, np.int32)
        for j, i in enumerate(keep):
            e_src[j] = remap[int(src[i])]
            e_dst[j] = remap[int(dst[i])]
        nf = np.zeros((pad_nodes, feats.shape[1]), np.float32)
        nf[: nodes.shape[0]] = feats[nodes]
        lab = np.full(pad_nodes, -1, np.int32)
        seed_local = [remap[int(v)] for v in seeds if int(v) in remap]
        lab[seed_local] = labels[seeds[: len(seed_local)]]
        batch = GraphBatch(
            node_feat=jnp.asarray(nf),
            senders=jnp.asarray(e_src),
            receivers=jnp.asarray(e_dst),
            edge_feat=None,
            pos=jnp.zeros((pad_nodes, 3), jnp.float32),
            graph_id=jnp.zeros((pad_nodes,), jnp.int32),
            n_graphs=1,
        )
        return {"graph": batch, "labels": jnp.asarray(lab)}


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

def recsys_batches(n_fields: int, rows_per_field: int, batch: int,
                   seed: int = 0):
    """Clickthrough-style batches with a planted preference rule."""
    rng = np.random.default_rng(seed)
    w_secret = rng.standard_normal(n_fields)
    while True:
        ids = rng.integers(0, rows_per_field, (batch, n_fields))
        signal = ((ids % 7) / 3.0 - 1.0) @ w_secret
        labels = (signal + 0.5 * rng.standard_normal(batch) > 0).astype(
            np.float32)
        yield {
            "ids": jnp.asarray(ids.astype(np.int32)),
            "labels": jnp.asarray(labels),
        }
