"""Serving launcher: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as tf


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b",
                    choices=[a for a in ARCH_IDS
                             if get_arch(a).family == "lm"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke
    params = tf.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
        .astype(np.int32))
    max_len = args.prompt_len + args.gen

    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: tf.prefill(cfg, p, t, max_len=max_len))
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))
    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tokens]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tokens)
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"[serve] arch={args.arch} (smoke config) batch={args.batch}")
    print(f"  prefill {args.prompt_len} tokens: {t_prefill*1e3:.1f} ms")
    print(f"  decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/(args.gen-1)*1e3:.1f} ms/token)")
    print(f"  generated ids[0]: {gen[0][:12]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
