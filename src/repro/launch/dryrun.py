import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the XLA_FLAGS assignment above MUST precede any jax import (jax
# locks the device count on first init), which is why it sits before the
# module docstring and all other imports.
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this records, to artifacts/dryrun/<mesh>/<arch>__<shape>.json:
  * memory_analysis  — per-device argument/output/temp/alias bytes
  * cost_analysis    — per-device HLO flops and bytes accessed
  * collective bytes — parsed from the compiled HLO text, summed per op kind
  * meta             — model_flops, param counts, step kind

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all
  PYTHONPATH=src python -m repro.launch.dryrun --arch fm --shape all
"""

import argparse
import json
import re
import time
import traceback

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all dtype[shape] terms in an HLO result type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind {count, bytes} summed over collective ops in compiled HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT )?%?[\w.\-]+ = (.*)$", ls)
        if not m:
            continue
        rest = m.group(1)
        opm = re.match(r"^((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*)) "
                       r"([a-z0-9\-]+)", rest)
        if not opm:
            continue
        result_type, op = opm.groups()
        # strip -start/-done suffixes (async collectives appear twice;
        # count only the -start or the plain form)
        base = op.replace("-start", "")
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base]["count"] += 1
            out[base]["bytes"] += _shape_bytes(result_type)
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_count"] = sum(
        v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> dict:
    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import SkippedCell, build_cell

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    arch = get_arch(arch_id)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    t0 = time.perf_counter()
    try:
        cell = build_cell(arch, shape_name, make_production_mesh(
            multi_pod=multi_pod))
    except SkippedCell as e:
        rec["status"] = "skipped"
        rec["reason"] = str(e)
        _write(out_dir, mesh_name, arch_id, shape_name, rec)
        return rec
    try:
        from repro.launch.hlo_cost import analyze_hlo

        mesh = make_production_mesh(multi_pod=multi_pod)
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        with mesh:
            lowered = jitted.lower(*cell.args)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }
        # raw XLA numbers (loop bodies counted ONCE — kept for reference)
        ca = compiled.cost_analysis() or {}
        rec["cost_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        # loop-corrected cost model (launch/hlo_cost.py): trip counts
        # multiplied, HBM bytes counted at fusion boundaries
        hlo_text = compiled.as_text()
        rec["cost"] = analyze_hlo(hlo_text)
        # flat op census (each collective op once, no trip scaling) — the
        # loop-corrected totals live in rec["cost"]["collectives"]
        rec["collectives_flat"] = parse_collectives(hlo_text)
        rec["collectives"] = {
            "total_bytes": rec["cost"]["collective_bytes"],
            "by_kind": rec["cost"]["collectives"],
        }
        rec["meta"] = cell.meta
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.perf_counter() - t0
    _write(out_dir, mesh_name, arch_id, shape_name, rec)
    return rec


def _write(out_dir, mesh_name, arch_id, shape_name, rec):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{arch_id}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    from repro.configs import ARCH_IDS, get_arch

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch_id in archs:
            arch = get_arch(arch_id)
            shapes = (list(arch.shapes) if args.shape == "all"
                      else [args.shape])
            for shape_name in shapes:
                path = os.path.join(args.out, mesh_name,
                                    f"{arch_id}__{shape_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skipped"):
                        print(f"[skip-existing] {mesh_name} {arch_id} "
                              f"{shape_name}")
                        continue
                rec = run_cell(arch_id, shape_name, multi, args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    gb = rec["memory"]["peak_estimate_bytes"] / 2**30
                    extra = (f"compile {rec['compile_s']:.1f}s "
                             f"peak/dev {gb:.2f} GiB "
                             f"flops/dev {rec['cost']['flops']:.3e} "
                             f"coll {rec['collectives']['total_bytes']:.3e}B")
                elif status == "error":
                    failures += 1
                    extra = rec["error"][:200]
                else:
                    extra = rec.get("reason", "")
                print(f"[{status}] {mesh_name} {arch_id} {shape_name} {extra}",
                      flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
