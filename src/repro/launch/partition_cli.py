"""Partitioner CLI: partition a generated or user-supplied graph.

    PYTHONPATH=src python -m repro.launch.partition_cli --graph grid \
        --size 96 --k 16 --out parts.npy
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.partition import PartitionConfig, partition
from repro.core.graph import build_csr_host
from repro.data import graphs as gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="grid",
                    choices=["grid", "cube", "rmat", "geo", "smallworld",
                             "edgelist"])
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--edges", default=None,
                    help="path to a .npy (E,2) edge list (--graph edgelist)")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--imbalance", type=float, default=0.03)
    ap.add_argument("--phi", type=float, default=0.999)
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "sorted", "ell"])
    ap.add_argument("--rebuild-every", type=int, default=0,
                    help="full ConnState rebuild period inside refinement "
                         "(0=never/incremental, 1=rebuild each iteration)")
    ap.add_argument("--coarse-target", type=int, default=4096,
                    help="stop coarsening at this many vertices")
    ap.add_argument("--max-levels", type=int, default=40,
                    help="coarsening depth cap")
    ap.add_argument("--coarsen-mode", default="device",
                    choices=["device", "host"],
                    help="device = jitted levels on a static shape schedule; "
                         "host = legacy per-level numpy repack")
    ap.add_argument("--bucket-ratio", type=float, default=1.6,
                    help="shape-schedule geometric shrink per rung")
    ap.add_argument("--bucket-safety", type=float, default=1.25,
                    help="headroom multiplier on the rung shrink")
    ap.add_argument("--bucket-align", type=int, default=64,
                    help="capacity rung alignment (bucket sharing)")
    ap.add_argument("--init", default="voronoi", choices=["voronoi", "random"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=None,
                    help="best-of-N trials, vmapped over one shared "
                         "hierarchy; the balanced lowest-cut trial wins "
                         "(default: len(--trial-seeds), else 1)")
    ap.add_argument("--trial-seeds", default=None,
                    help="comma-separated per-trial init seeds "
                         "(default: seed..seed+trials-1)")
    ap.add_argument("--out", default=None, help="write parts as .npy")
    args = ap.parse_args(argv)

    if args.graph == "edgelist":
        e = np.load(args.edges)
        g = build_csr_host(int(e.max()) + 1, e)
    elif args.graph == "grid":
        g = gen.grid2d(args.size, args.size)
    elif args.graph == "cube":
        s = max(4, round(args.size ** (2 / 3)))
        g = gen.grid3d(s, s, s)
    elif args.graph == "rmat":
        g = gen.rmat(scale=max(8, args.size.bit_length() + 2))
    elif args.graph == "geo":
        g = gen.random_geometric(args.size * args.size, seed=args.seed)
    else:
        g = gen.small_world(args.size * args.size, seed=args.seed)

    trial_seeds = (
        tuple(int(s) for s in args.trial_seeds.split(","))
        if args.trial_seeds else None
    )
    if args.trials is None:  # the seed list determines the trial count
        args.trials = len(trial_seeds) if trial_seeds else 1
    cfg = PartitionConfig(k=args.k, lam=args.imbalance, phi=args.phi,
                          backend=args.backend, init_method=args.init,
                          rebuild_every=args.rebuild_every, seed=args.seed,
                          coarse_target=args.coarse_target,
                          max_levels=args.max_levels,
                          coarsen_mode=args.coarsen_mode,
                          bucket_ratio=args.bucket_ratio,
                          bucket_safety=args.bucket_safety,
                          bucket_align=args.bucket_align,
                          trials=args.trials, trial_seeds=trial_seeds)
    res = partition(g, cfg)
    report = {
        "n": int(g.n), "m": int(g.m) // 2, "k": args.k,
        "cut": res.cut, "imbalance": res.imbalance,
        "balanced": res.balanced, "levels": res.levels,
        "trials": res.trials, "best_trial": res.best_trial,
        "trial_cuts": res.trial_cuts, "trial_balanced": res.trial_balanced,
        "times": res.times,
        "level_stats": [
            {kk: st[kk] for kk in ("level", "n", "m", "n_max", "m_max")}
            for st in res.level_stats
        ],
    }
    print(json.dumps(report, indent=1))
    if args.out:
        np.save(args.out, np.asarray(res.parts)[: int(g.n)])
        print(f"parts -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
