"""Partitioner CLI: partition generated or user-supplied graphs.

Single graph:

    PYTHONPATH=src python -m repro.launch.partition_cli --graph grid \
        --size 96 --k 16 --out parts.npy

Fleet mode (DESIGN.md §10) — many graphs, shape-bucketed and batched
through one V-cycle program per bucket:

    PYTHONPATH=src python -m repro.launch.partition_cli \
        --fleet grid:96 grid:90 cube:12 --k 16

Exits nonzero (with a stderr diagnostic) when the selected partition of
any requested graph is unbalanced, so CI and fleet schedulers can gate on
the return code.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core.partition import PartitionConfig, partition, partition_fleet
from repro.core.graph import build_csr_host
from repro.data import graphs as gen

GRAPH_KINDS = ("grid", "cube", "rmat", "geo", "smallworld", "edgelist")


def _make_graph(kind: str, size: int, seed: int, edges: str | None = None):
    if kind == "edgelist":
        if not edges:
            raise SystemExit("--graph edgelist requires --edges PATH")
        e = np.load(edges)
        return build_csr_host(int(e.max()) + 1, e)
    if kind == "grid":
        return gen.grid2d(size, size)
    if kind == "cube":
        s = max(4, round(size ** (2 / 3)))
        return gen.grid3d(s, s, s)
    if kind == "rmat":
        return gen.rmat(scale=max(8, size.bit_length() + 2))
    if kind == "geo":
        return gen.random_geometric(size * size, seed=seed)
    if kind == "smallworld":
        return gen.small_world(size * size, seed=seed)
    raise SystemExit(f"unknown graph kind {kind!r}")


def _parse_fleet_spec(spec: str, default_size: int, default_seed: int):
    """``name[:size[:seed]]`` -> (kind, size, seed)."""
    parts = spec.split(":")
    kind = parts[0]
    try:
        if kind not in GRAPH_KINDS or kind == "edgelist" or len(parts) > 3:
            raise ValueError
        size = int(parts[1]) if len(parts) > 1 else default_size
        seed = int(parts[2]) if len(parts) > 2 else default_seed
    except ValueError:
        raise SystemExit(
            f"bad --fleet spec {spec!r}: expected name[:size[:seed]] with "
            f"name in {GRAPH_KINDS[:-1]} and integer size/seed"
        ) from None
    return kind, size, seed


def _graph_report(g, res, k):
    return {
        "n": int(g.n), "m": int(g.m) // 2, "k": k,
        "cut": res.cut, "imbalance": res.imbalance,
        "balanced": res.balanced, "levels": res.levels,
        "trials": res.trials, "best_trial": res.best_trial,
        "trial_cuts": res.trial_cuts, "trial_balanced": res.trial_balanced,
        "times": res.times,
        "level_stats": [
            {kk: st[kk] for kk in ("level", "n", "m", "n_max", "m_max")
             if kk in st}
            for st in res.level_stats
        ],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="grid", choices=list(GRAPH_KINDS))
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--edges", default=None,
                    help="path to a .npy (E,2) edge list (--graph edgelist)")
    ap.add_argument("--fleet", nargs="+", default=None, metavar="SPEC",
                    help="fleet mode: partition several graphs in one "
                         "shape-bucketed batched run; SPEC is "
                         "name[:size[:seed]], e.g. grid:96 cube:12")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--imbalance", type=float, default=0.03)
    ap.add_argument("--phi", type=float, default=0.999)
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "sorted", "ell"])
    ap.add_argument("--rebuild-every", type=int, default=0,
                    help="full ConnState rebuild period inside refinement "
                         "(0=never/incremental, 1=rebuild each iteration)")
    ap.add_argument("--coarse-target", type=int, default=4096,
                    help="stop coarsening at this many vertices")
    ap.add_argument("--max-levels", type=int, default=40,
                    help="coarsening depth cap")
    ap.add_argument("--coarsen-mode", default="device",
                    choices=["device", "host"],
                    help="device = jitted levels on a static shape schedule; "
                         "host = legacy per-level numpy repack (single-graph "
                         "mode only)")
    ap.add_argument("--bucket-ratio", type=float, default=1.6,
                    help="shape-schedule geometric shrink per rung")
    ap.add_argument("--bucket-safety", type=float, default=1.25,
                    help="headroom multiplier on the rung shrink")
    ap.add_argument("--bucket-align", type=int, default=64,
                    help="capacity rung alignment (bucket sharing)")
    ap.add_argument("--init", default="voronoi", choices=["voronoi", "random"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=None,
                    help="best-of-N trials, vmapped over one shared "
                         "hierarchy; the balanced lowest-cut trial wins "
                         "(default: len(--trial-seeds), else 1)")
    ap.add_argument("--trial-seeds", default=None,
                    help="comma-separated per-trial init seeds "
                         "(default: seed..seed+trials-1)")
    ap.add_argument("--allow-unbalanced", action="store_true",
                    help="exit 0 even when the selected partition misses "
                         "the balance constraint")
    ap.add_argument("--out", default=None, help="write parts as .npy "
                    "(single-graph mode only)")
    args = ap.parse_args(argv)

    trial_seeds = (
        tuple(int(s) for s in args.trial_seeds.split(","))
        if args.trial_seeds else None
    )
    if args.trials is None:  # the seed list determines the trial count
        args.trials = len(trial_seeds) if trial_seeds else 1
    cfg = PartitionConfig(k=args.k, lam=args.imbalance, phi=args.phi,
                          backend=args.backend, init_method=args.init,
                          rebuild_every=args.rebuild_every, seed=args.seed,
                          coarse_target=args.coarse_target,
                          max_levels=args.max_levels,
                          coarsen_mode=args.coarsen_mode,
                          bucket_ratio=args.bucket_ratio,
                          bucket_safety=args.bucket_safety,
                          bucket_align=args.bucket_align,
                          trials=args.trials, trial_seeds=trial_seeds)

    if args.fleet:
        if args.out or args.edges:
            raise SystemExit(
                "--out/--edges are single-graph options and would be "
                "silently ignored in fleet mode — drop them or run per "
                "graph"
            )
        specs = [_parse_fleet_spec(s, args.size, args.seed)
                 for s in args.fleet]
        dupes = sorted({
            f"{kind}:{size}:{seed}" for i, (kind, size, seed)
            in enumerate(specs) if (kind, size, seed) in specs[:i]
        })
        if dupes:
            print(
                f"ERROR: duplicate --fleet member name(s): "
                f"{', '.join(dupes)} — every fleet member must be unique, "
                "or downstream consumers keying reports by spec would "
                "silently collapse entries (give duplicates distinct "
                "seeds, e.g. grid:96:0 grid:96:1)",
                file=sys.stderr,
            )
            return 2
        graphs = [_make_graph(kind, size, seed)
                  for kind, size, seed in specs]
        fres = partition_fleet(graphs, cfg)
        report = {
            "fleet": [
                {"spec": args.fleet[i]}
                | _graph_report(graphs[i], fres.results[i], args.k)
                for i in range(len(graphs))
            ],
            "buckets": [
                {"capacity": list(b.capacity), "members": b.indices,
                 "levels": b.levels}
                for b in fres.buckets
            ],
            "times": fres.times,
        }
        print(json.dumps(report, indent=1))
        unbalanced = [args.fleet[i] for i, r in enumerate(fres.results)
                      if not r.balanced]
        if unbalanced and not args.allow_unbalanced:
            print(
                f"ERROR: selected partition unbalanced for "
                f"{len(unbalanced)}/{len(graphs)} fleet member(s) "
                f"({', '.join(unbalanced)}) at lam={args.imbalance} — "
                "failing so callers can gate on the exit code "
                "(--allow-unbalanced to override)",
                file=sys.stderr,
            )
            return 1
        return 0

    g = _make_graph(args.graph, args.size, args.seed, edges=args.edges)
    res = partition(g, cfg)
    print(json.dumps(_graph_report(g, res, args.k), indent=1))
    if args.out:
        np.save(args.out, np.asarray(res.parts)[: int(g.n)])
        print(f"parts -> {args.out}")
    if not res.balanced and not args.allow_unbalanced:
        print(
            f"ERROR: selected trial {res.best_trial} is unbalanced "
            f"(imbalance {res.imbalance:.4f} > lam {args.imbalance}) — "
            "failing so fleet/CI invocations can gate on the exit code "
            "(--allow-unbalanced to override)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
