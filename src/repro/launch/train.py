"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 200 --ckpt-dir /tmp/ck

On this CPU container the smoke configs run end-to-end (fault-tolerant
loop, checkpoints, straggler watchdog); on a real fleet the same entry
point builds the production mesh and shards per launch/sharding.py (the
dry-run proves those programs compile for 256/512 chips).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data import synthetic as synth
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_cell, materialize_cell
from repro.optim import adamw
from repro.train import loop as train_loop


def make_data(arch, cell, smoke: bool, seed: int = 0):
    """Batch iterator matched to the cell's batch spec."""
    fam = arch.family
    batch_sds = cell.args[2]
    if fam == "lm":
        cfg = arch.smoke if smoke else arch.config
        b, s = batch_sds["tokens"].shape
        return synth.lm_batches(cfg.vocab, b, s, seed=seed)
    if fam == "recsys":
        cfg = arch.smoke if smoke else arch.config
        b = batch_sds["ids"].shape[0]
        return synth.recsys_batches(cfg.n_fields, cfg.rows_per_field, b,
                                    seed=seed)
    # gnn: re-materialize a fixed synthetic batch (full-batch training)
    fixed = materialize_cell(cell, seed=seed)[2]

    def gen():
        while True:
            yield fixed

    return gen()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    shape = args.shape
    if arch.family == "gnn" and shape == "train_4k":
        shape = "full_graph_sm"
    if arch.family == "recsys" and shape == "train_4k":
        shape = "train_batch"
    mesh = make_host_mesh()
    cell = build_cell(arch, shape, mesh, smoke=args.smoke)
    assert cell.meta["kind"] == "train", "use serve.py for inference shapes"

    params, opt_state, _ = materialize_cell(cell, seed=args.seed)
    data = make_data(arch, cell, args.smoke, seed=args.seed)

    step3 = jax.jit(cell.step_fn, donate_argnums=(0, 1))

    def step(params, opt_state, err, batch):
        p, o, m = step3(params, opt_state, batch)
        return p, o, err, m

    lc = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, resume=True, log_every=10,
        compress_grads=args.compress_grads)
    st = train_loop.TrainState(params, opt_state, 0)
    final = train_loop.run(lc, st, step, data)
    print(f"[train] finished at step {final.step}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
