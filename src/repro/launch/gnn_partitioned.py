"""Partition-aware distributed GNN training (the §Perf hillclimb built on
the paper's technique).

Layout produced by the Jet partitioner (dist/partition_aware.py): each
device owns a contiguous node block; edges live on their receiver's
device; senders reference either a local slot or a halo slot.  Message
passing runs under shard_map: per layer, each device exports its boundary
features once (all_gather of (H_cap, F) blocks) and aggregates locally —
replacing the naive mode's full-node all-gather + all-reduce pair.

Collective bytes per layer:
    naive       : N*F (gather) + N*F (reduce)        = 2*N*F
    partitioned : halo_frac * N * F                  (one gather)
so the partitioner's cut quality IS the communication bill.

Implemented for meshgraphnet (the hillclimb cell); the halo-exchange core
is model-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as sh
from repro.launch.steps import Cell, _pad512, _sds
from repro.models.gnn import meshgraphnet
from repro.models.gnn.common import mlp_apply
from repro.optim import adamw


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map landed after 0.4.x)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # pre-rename releases take check_rep instead
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _sizes(shape, mesh, halo_frac: float):
    n = _pad512(shape.get("n_nodes", shape.get("pad_nodes")))
    e = _pad512(shape.get("n_edges", shape.get("pad_edges")))
    d_devices = 1
    for a in mesh.axis_names:
        d_devices *= mesh.shape[a]
    n_l = n // d_devices
    e_l = e // d_devices
    h_cap = max(8, int(round(halo_frac * n_l / 8)) * 8)
    return n, e, d_devices, n_l, e_l, h_cap


def partitioned_batch_sds(shape, mesh, halo_frac: float, d_feat: int):
    n, e, d, n_l, e_l, h_cap = _sizes(shape, mesh, halo_frac)
    return {
        "node_feat": _sds((n, d_feat), jnp.float32),
        "pos": _sds((n, 3), jnp.float32),
        "target": _sds((n, 2), jnp.float32),
        # local sender index in [0, n_l + d*h_cap]  (ghost = n_l + d*h_cap)
        "senders": _sds((e,), jnp.int32),
        # local receiver index in [0, n_l]          (ghost = n_l)
        "receivers": _sds((e,), jnp.int32),
        # per-device boundary export list (local indices)
        "halo_send": _sds((d * h_cap,), jnp.int32),
        "valid_edge": _sds((e,), jnp.float32),
        "valid_node": _sds((n,), jnp.float32),
    }


def build_partitioned_batch(n, feats, pos, target, edges, parts, k,
                            n_l, e_cap_total, h_cap):
    """Host-side layout builder: partition plan -> shard_map arrays.

    edges (E, 2) directed (sender, receiver); each edge is owned by its
    receiver's device.  Returns the dict matching partitioned_batch_sds
    plus drop statistics (edges beyond per-device capacity or halo slots
    beyond h_cap are dropped and counted).
    """
    import numpy as np

    p = np.asarray(parts)[:n]
    order = np.argsort(p, kind="stable")
    slot_of = np.full(n, -1, np.int64)
    dev_of = np.empty(n, np.int64)
    counts = np.bincount(p, minlength=k)
    assert counts.max() <= n_l, (counts.max(), n_l)
    offs = np.zeros(k, np.int64)
    for v in order:
        d = p[v]
        slot_of[v] = offs[d]
        dev_of[v] = d
        offs[d] += 1
    # per-device exports: boundary vertices referenced by other devices
    src, dst = edges[:, 0], edges[:, 1]
    remote = dev_of[src] != dev_of[dst]
    exports = [dict() for _ in range(k)]  # vertex -> halo slot
    dropped_halo = 0
    for u in np.unique(src[remote]):
        d = dev_of[u]
        if len(exports[d]) < h_cap:
            exports[d][int(u)] = len(exports[d])
        else:
            dropped_halo += 1
    halo_send = np.zeros((k, h_cap), np.int64)
    for d in range(k):
        for u, s in exports[d].items():
            halo_send[d, s] = slot_of[u]
    # per-device edge lists
    e_cap = e_cap_total // k
    ghost_snd = n_l + k * h_cap
    senders = np.full((k, e_cap), ghost_snd, np.int64)
    receivers = np.full((k, e_cap), n_l, np.int64)
    valid_e = np.zeros((k, e_cap), np.float32)
    fill = np.zeros(k, np.int64)
    dropped_edges = 0
    for i in range(edges.shape[0]):
        u, v = int(src[i]), int(dst[i])
        d = int(dev_of[v])
        if fill[d] >= e_cap:
            dropped_edges += 1
            continue
        j = fill[d]
        receivers[d, j] = slot_of[v]
        if dev_of[u] == d:
            senders[d, j] = slot_of[u]
        else:
            s = exports[int(dev_of[u])].get(u)
            if s is None:
                dropped_edges += 1
                continue
            senders[d, j] = n_l + dev_of[u] * h_cap + s
        valid_e[d, j] = 1.0
        fill[d] += 1
    # node arrays in device-block layout
    F = feats.shape[1]
    nf = np.zeros((k, n_l, F), np.float32)
    ps = np.zeros((k, n_l, 3), np.float32)
    tg = np.zeros((k, n_l, target.shape[1]), np.float32)
    vn = np.zeros((k, n_l), np.float32)
    for v in range(n):
        d, s = dev_of[v], slot_of[v]
        nf[d, s] = feats[v]
        ps[d, s] = pos[v]
        tg[d, s] = target[v]
        vn[d, s] = 1.0
    import jax.numpy as jnp

    batch = {
        "node_feat": jnp.asarray(nf.reshape(k * n_l, F)),
        "pos": jnp.asarray(ps.reshape(k * n_l, 3)),
        "target": jnp.asarray(tg.reshape(k * n_l, -1)),
        "senders": jnp.asarray(senders.reshape(-1).astype(np.int32)),
        "receivers": jnp.asarray(receivers.reshape(-1).astype(np.int32)),
        "halo_send": jnp.asarray(halo_send.reshape(-1).astype(np.int32)),
        "valid_edge": jnp.asarray(valid_e.reshape(-1)),
        "valid_node": jnp.asarray(vn.reshape(-1)),
    }
    stats = {"dropped_edges": dropped_edges, "dropped_halo": dropped_halo}
    return batch, stats


def partitioned_gnn_cell(arch, shape_name, mesh, smoke=False, tuning=None):
    assert arch.id == "meshgraphnet", "partitioned mode: meshgraphnet only"
    tuning = tuning or {}
    halo_frac = tuning.get("halo_frac", 0.25)
    cfg = arch.smoke if smoke else arch.config
    shape = arch.shapes[shape_name]
    cfg = dataclasses.replace(cfg, d_in=shape["d_feat"])
    n, e, d_devices, n_l, e_l, h_cap = _sizes(shape, mesh, halo_frac)
    axes = tuple(mesh.axis_names)

    params_sds = jax.eval_shape(partial(meshgraphnet.init_params, cfg),
                                jax.random.key(0))
    p_sh = sh.gnn_param_sharding(mesh, params_sds)
    opt_sds = jax.eval_shape(adamw.init_state, params_sds)
    o_sh = sh.opt_sharding_like(p_sh, mesh)
    batch_sds = partitioned_batch_sds(shape, mesh, halo_frac, shape["d_feat"])
    b_sh = {k: NamedSharding(mesh, P(axes, *([None] * (len(v.shape) - 1))))
            for k, v in batch_sds.items()}

    def local_loss(params, b):
        """Runs per shard under shard_map; returns replicated scalar loss."""
        nf = b["node_feat"]          # (n_l, F)
        pos = b["pos"]               # (n_l, 3)
        tgt = b["target"]
        snd = b["senders"]           # (e_l,)
        rcv = b["receivers"]         # (e_l,)
        hsend = b["halo_send"]       # (h_cap,) per shard
        v_e = b["valid_edge"][:, None]
        v_n = b["valid_node"][:, None]

        def exchange(x):             # (n_l, F) -> (n_l + D*h_cap + 1, F)
            boundary = x[jnp.clip(hsend, 0, n_l - 1)]
            halo = jax.lax.all_gather(boundary, axis_name=axes)
            halo = halo.reshape(-1, x.shape[-1])
            ghost = jnp.zeros((1, x.shape[-1]), x.dtype)
            return jnp.concatenate([x, halo, ghost], 0)

        def gather_src(x_ext, idx):
            return x_ext[jnp.clip(idx, 0, n_l + d_devices * h_cap)]

        # edge geometry: receiver-local pos minus (possibly remote) sender pos
        pos_ext = exchange(pos)
        rel = (pos[jnp.clip(rcv, 0, n_l - 1)]
               - gather_src(pos_ext, snd)) * v_e
        dist = jnp.linalg.norm(rel + 1e-12, axis=-1, keepdims=True) * v_e
        efeat = jnp.concatenate([rel, dist], -1)

        h = mlp_apply(params["enc_n"], nf, act=jax.nn.relu)
        ee = mlp_apply(params["enc_e"], efeat, act=jax.nn.relu) * v_e

        @jax.checkpoint
        def block(carry, blk):
            h, ee = carry
            h_ext = exchange(h)
            hs = gather_src(h_ext, snd)
            hr = h[jnp.clip(rcv, 0, n_l - 1)]
            ee = ee + mlp_apply(blk["edge"],
                                jnp.concatenate([ee, hs, hr], -1),
                                act=jax.nn.relu) * v_e
            agg = jax.ops.segment_sum(ee, rcv, num_segments=n_l + 1)[:n_l]
            h = h + mlp_apply(blk["node"], jnp.concatenate([h, agg], -1),
                              act=jax.nn.relu)
            return (h, ee), None

        (h, ee), _ = jax.lax.scan(block, (h, ee), params["blocks"])
        pred = mlp_apply(params["dec"], h, act=jax.nn.relu)
        se = jnp.sum(((pred - tgt) ** 2) * v_n)
        cnt = jnp.sum(v_n) * cfg.d_out
        se = jax.lax.psum(se, axis_name=axes)
        cnt = jax.lax.psum(cnt, axis_name=axes)
        return se / jnp.maximum(cnt, 1.0)

    in_specs = (
        jax.tree.map(lambda _: P(), params_sds),
        {k: P(axes, *([None] * (len(v.shape) - 1)))
         for k, v in batch_sds.items()},
    )
    shard_loss = _shard_map(local_loss, mesh, in_specs, P())

    opt_cfg = adamw.AdamWConfig()

    def train_step(params, opt_state, b):
        loss, grads = jax.value_and_grad(shard_loss)(params, b)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    fwd = meshgraphnet  # for flops estimate reuse
    from repro.launch.steps import gnn_model_flops

    return Cell(
        step_fn=train_step,
        args=(params_sds, opt_sds, batch_sds),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate=(0, 1),
        meta={
            "kind": "train",
            "param_count": cfg.param_count(),
            "active_param_count": cfg.param_count(),
            "model_flops": gnn_model_flops(arch.id, cfg, shape),
            "tokens": n,
            "mode": "partitioned",
            "halo_frac": halo_frac,
            "h_cap": h_cap,
        },
    )
