"""Per-family sharding rules (GSPMD partition specs by parameter path).

LM: 2D FSDP+TP — d_model sharded over 'data', heads/ffn/vocab/experts over
'model'; 'pod' (when present) is pure DP (params replicated across pods,
gradients all-reduced over DCN).  KV caches shard batch over data and
sequence over model (FlashDecoding-style split-K when batch is small).

GNN (baseline mode): params replicated; node/edge arrays sharded over all
mesh axes.  RecSys: embedding table sharded over (data, model) rows.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_param_sharding(mesh, params_shape):
    dp = "data"

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.endswith("embed"):
            return _ns(mesh, "model", dp)
        if "moe/router" in name:
            return _ns(mesh, None, dp, None)
        if "moe/shared/w_down" in name:
            return _ns(mesh, None, "model", dp)
        if "moe/shared" in name:
            return _ns(mesh, None, dp, "model")
        if "moe/w_down" in name:                      # (L, E, f, d)
            return _ns(mesh, None, "model", None, dp)
        if "moe/" in name:                            # (L, E, d, f)
            return _ns(mesh, None, "model", dp, None)
        if name.endswith(("wq", "wk", "wv")):
            return _ns(mesh, None, dp, "model")
        if name.endswith("w_dkv"):                    # (L, d, r) — r replicated
            return _ns(mesh, None, dp, None)
        if name.endswith("w_ukv"):                    # (L, r, H*(nope+dv))
            return _ns(mesh, None, None, "model")
        if name.endswith(("wo", "w_down")):           # (L, in, d)
            return _ns(mesh, None, "model", dp)
        if name.endswith(("w_gate", "w_up")):         # (L, d, ff)
            return _ns(mesh, None, dp, "model")
        if nd <= 2:                                   # norms, scalars
            return _ns(mesh)
        return _ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def lm_param_sharding_zero1(mesh, params_shape):
    """ZeRO-1: params replicated over 'data' (sharded over 'model' only);
    optimizer state keeps the full 2D FSDP sharding.  Weight all-gathers
    disappear; the per-step cost becomes one param-sized broadcast when the
    2D-sharded update is applied (GSPMD inserts it at the adamw subtract).
    """
    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.endswith("embed"):
            return _ns(mesh, "model", None)
        if "moe/router" in name:
            return _ns(mesh, None, None, None)
        if "moe/shared/w_down" in name:
            return _ns(mesh, None, "model", None)
        if "moe/shared" in name:
            return _ns(mesh, None, None, "model")
        if "moe/w_down" in name:
            return _ns(mesh, None, "model", None, None)
        if "moe/" in name:
            return _ns(mesh, None, "model", None, None)
        if name.endswith(("wq", "wk", "wv", "w_gate", "w_up")):
            return _ns(mesh, None, None, "model")
        if name.endswith("w_dkv"):
            return _ns(mesh, None, None, None)
        if name.endswith("w_ukv"):
            return _ns(mesh, None, None, "model")
        if name.endswith(("wo", "w_down")):
            return _ns(mesh, None, "model", None)
        return _ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def lm_batch_sharding(mesh):
    dp = dp_axes(mesh)
    return {"tokens": _ns(mesh, dp, None), "labels": _ns(mesh, dp, None)}


def lm_cache_sharding(mesh, cache_shape, batch: int):
    """KV caches: batch over dp when divisible, else sequence over all axes.

    GQA cache leaves: (L, B, Hkv, S, Dh); MLA: (L, B, S, r).
    """
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    big_b = batch % dp_size == 0 and batch >= dp_size

    def rule(path, leaf):
        name = _path_str(path)
        if name == "len":
            return _ns(mesh)
        nd = len(leaf.shape)
        if nd == 5:  # (L, B, Hkv, S, Dh)
            if big_b:
                return _ns(mesh, None, dp, None, "model", None)
            return _ns(mesh, None, None, None, (*dp, "model"), None)
        if nd == 4:  # (L, B, S, r) MLA compressed
            if big_b:
                return _ns(mesh, None, dp, "model", None)
            return _ns(mesh, None, None, (*dp, "model"), None)
        return _ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def lm_logits_sharding(mesh):
    return _ns(mesh, dp_axes(mesh), "model")


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_param_sharding(mesh, params_shape):
    return jax.tree_util.tree_map(lambda _: _ns(mesh), params_shape)


def gnn_batch_sharding(mesh, batch_shape):
    """Node/edge arrays row-sharded over every mesh axis."""
    all_axes = tuple(mesh.axis_names)

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.endswith(("senders", "receivers", "graph_id")):
            return _ns(mesh, all_axes)
        if name.endswith(("node_feat", "pos")):
            return _ns(mesh, all_axes, None)
        if name.endswith(("labels",)) and nd == 1:
            return _ns(mesh, all_axes)
        if name.endswith("target"):
            return _ns(mesh, all_axes, None)
        if name.endswith("energy"):
            return _ns(mesh)
        return _ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def fm_param_sharding(mesh, params_shape):
    dp = "data"

    def rule(path, leaf):
        name = _path_str(path)
        if name.endswith("table"):
            return _ns(mesh, (dp, "model"), None)
        if name.endswith("linear"):
            return _ns(mesh, (dp, "model"))
        return _ns(mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def fm_batch_sharding(mesh):
    dp = dp_axes(mesh)
    return {"ids": _ns(mesh, dp, None), "labels": _ns(mesh, dp)}


def opt_sharding_like(param_sharding, mesh):
    """AdamW state: mu/nu mirror params; step replicated."""
    return {
        "mu": param_sharding,
        "nu": param_sharding,
        "step": _ns(mesh),
    }
