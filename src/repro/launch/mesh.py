"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the pod axis is
the DCN (inter-pod) dimension — pure data parallelism across pods, FSDP
within a pod over 'data', tensor/expert parallelism over 'model'.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType (and the axis_types
    kwarg) only exist on newer jax; older versions default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the locally available devices (tests / smoke runs)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return compat_make_mesh((data, model_axis), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes that shard the batch dimension."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
