"""Partition-as-a-service: async micro-batching over fleet buckets (§11).

The §10 fleet machinery made the whole V-cycle batch-polymorphic, but
every caller still hands `partition_fleet` a pre-assembled fleet and
waits.  This module adds the missing traffic layer:

* :class:`PartitionServer` accepts concurrent partition requests (graph +
  k + trials + seed), coalesces them over a configurable window into
  shape-bucketed fleets on a FIXED §8 capacity ladder, dispatches each
  bucket through :func:`~repro.core.partition.partition_fleet_stacked`,
  and routes per-member results back to their callers.  Every response is
  bit-identical to a standalone ``partition()`` call with the same
  config — batching changes the schedule, never the values.

* Warm-start subsystem: :meth:`PartitionServer.warmup` is an explicit AOT
  pass that precompiles the (rung, k) signature grid from representative
  shapes, and :func:`enable_compile_cache` wires JAX's persistent
  compilation cache so a cold process re-reaches steady-state latency
  from disk instead of from XLA.

Batch width discipline: every dispatched bucket is padded (with filler
copies of its first member) or split to exactly ``ServeConfig.lanes``
lanes, so the batch axis never enters the compile-key degrees of freedom
— one executable per (rung, k) signature, whatever the arrival pattern.

    server = PartitionServer(ServeConfig(ladder_n=1024, ladder_m=8192))
    server.warmup([gen.grid2d(16, 16)], ks=(8,))
    async with server:
        res = await server.submit(g, k=8)
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace

import jax

from repro.core import graph as gr
from repro.core.coarsen import _round_up, shape_schedule
from repro.core.partition import (
    PartitionConfig, PartitionResult, partition_fleet_stacked,
    uncoarsen_level_fleet,
)


# ---------------------------------------------------------------------------
# Persistent compilation cache (warm starts across processes)
# ---------------------------------------------------------------------------

class CompileCacheStats:
    """Counter sink for JAX's compilation-cache monitoring events.

    XLA emits ``/jax/compilation_cache/cache_hits`` / ``cache_misses``
    events only when the persistent cache is enabled; a miss is a real
    XLA compile, a hit is an executable deserialized from disk.  The
    serve bench gates "zero new executables after warmup" on the miss
    delta.
    """

    def __init__(self):
        self.counts: dict[str, int] = {}

    def __call__(self, name: str, **kw) -> None:
        if name.startswith("/jax/compilation_cache/"):
            key = name.rsplit("/", 1)[-1]
            self.counts[key] = self.counts.get(key, 0) + 1

    def snapshot(self) -> dict[str, int]:
        return dict(self.counts)

    @staticmethod
    def delta(before: dict, after: dict) -> dict[str, int]:
        return {k: after.get(k, 0) - before.get(k, 0)
                for k in set(before) | set(after)}


_CACHE_STATS: CompileCacheStats | None = None


def cache_stats() -> CompileCacheStats:
    """The process-wide event listener (registered once, lazily)."""
    global _CACHE_STATS
    if _CACHE_STATS is None:
        _CACHE_STATS = CompileCacheStats()
        jax.monitoring.register_event_listener(_CACHE_STATS)
    return _CACHE_STATS


def enable_compile_cache(cache_dir: str) -> CompileCacheStats:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds are dropped to zero so every executable persists — the
    partitioner's per-rung programs are small but numerous, exactly the
    population the default min-compile-time filter would skip.  Returns
    the hit/miss counter listener.
    """
    from jax.experimental.compilation_cache import compilation_cache as cc

    stats = cache_stats()  # register BEFORE the first compile
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    # any jit that ran before this call (repro modules compile helpers at
    # import) memoizes the cache object as "disabled"; reset so the new
    # dir takes effect
    cc.reset_cache()
    return stats


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

@dataclass
class ServeConfig:
    """Serving knobs; ``partition`` holds the per-request defaults.

    ``ladder_n``/``ladder_m`` pin the top rung of the serve-wide capacity
    ladder — requests larger than the top rung are rejected at admission.
    ``window_s`` is the coalescing window: the batcher collects requests
    for this long after the first arrival before dispatching.  ``lanes``
    is the fixed batch width every dispatched bucket is padded/split to.
    """

    ladder_n: int = 4096
    ladder_m: int = 32768
    window_s: float = 0.002
    lanes: int = 4
    max_batch: int = 64            # requests per coalesce round, max
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    compile_cache: str | None = None


@dataclass
class _Request:
    graph: object
    cfg: PartitionConfig
    cfg_key: tuple       # grouping key: (k, trials, seed, trial_seeds)
    future: asyncio.Future
    t_enqueue: float


def _resolve_cfg(base: PartitionConfig, k, trials, seed, trial_seeds):
    cfg = base
    if k is not None:
        cfg = replace(cfg, k=int(k))
    if trials is not None:
        cfg = replace(cfg, trials=int(trials))
    if seed is not None:
        cfg = replace(cfg, seed=int(seed))
    if trial_seeds is not None:
        cfg = replace(cfg, trial_seeds=tuple(int(s) for s in trial_seeds))
    return cfg


class PartitionServer:
    """Async micro-batching front end over ``partition_fleet_stacked``.

    Lifecycle: construct, optionally :meth:`warmup`, then ``async with``
    (or :meth:`start` / :meth:`stop`).  :meth:`submit` is awaitable and
    safe to call concurrently from many tasks; requests sharing a
    coalescing window and a config signature (k, trials, seed) are batched
    into one fleet dispatch, shape-bucketed on the pinned ladder.

    Sync accounting per dispatch (DESIGN.md §11): one batched (n, m)
    admission fetch per flush, one (lanes, 3) stat fetch per coarsening
    level per bucket, and ONE blocking transfer for the whole dispatch's
    results — all amortized over every request in the batch.
    """

    def __init__(self, cfg: ServeConfig):
        if cfg.lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {cfg.lanes}")
        self.cfg = cfg
        p = cfg.partition
        self.schedule = shape_schedule(
            _round_up(cfg.ladder_n, p.bucket_align),
            _round_up(cfg.ladder_m, p.bucket_align),
            ratio=p.bucket_ratio, safety=p.bucket_safety,
            stall_ratio=p.stall_ratio, align=p.bucket_align,
        )
        if cfg.compile_cache:
            enable_compile_cache(cfg.compile_cache)
        self._queue: asyncio.Queue | None = None
        self._task: asyncio.Task | None = None
        self._pool: ThreadPoolExecutor | None = None
        # per-item records are bounded so a long-lived server doesn't
        # accumulate memory with traffic; the counters are exact forever,
        # the latency percentiles and signature logs cover a recent window
        # (far larger than any bench run, which reads them whole)
        self.stats = {
            "requests": 0, "responses": 0, "rejected": 0, "dispatches": 0,
            "buckets": 0, "filler_lanes": 0,
            "occupancy_hist": {},      # real lanes per dispatched bucket
            "latency_s": deque(maxlen=8192),  # enqueue -> response
        }
        self.dispatch_log: deque = deque(maxlen=2048)  # signature records
        self.warmup_log: deque = deque(maxlen=2048)    # same, AOT grid

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "PartitionServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._queue = asyncio.Queue()
        # one worker: device dispatches serialize, the event loop keeps
        # coalescing the next window while the current batch computes
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="jet-serve")
        self._task = asyncio.create_task(self._batch_loop())
        return self

    async def stop(self) -> None:
        if self._task is None:
            return
        await self._queue.put(None)  # drain sentinel: flush, then exit
        await self._task
        # a submit racing stop() can enqueue behind the sentinel; fail
        # those futures instead of leaving their callers awaiting forever
        while not self._queue.empty():
            req = self._queue.get_nowait()
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("server stopped before dispatch"))
        self._pool.shutdown(wait=True)  # all dispatches already gathered
        self._pool = None
        self._task = None
        self._queue = None

    async def __aenter__(self):
        return await self.start()

    async def __aexit__(self, *exc):
        await self.stop()

    # -- request path ------------------------------------------------------

    def _admissible(self, g) -> bool:
        """Host-only fast path; falls back to one (n, m) fetch only when
        the graph's own padding exceeds the ladder top."""
        n_top = max(nc for nc, _ in self.schedule)
        m_top = max(mc for _, mc in self.schedule)
        if g.n_max <= n_top and g.m_max <= m_top:
            return True
        return int(g.n) <= n_top and int(g.m) <= m_top

    async def submit(self, graph, *, k=None, trials=None, seed=None,
                     trial_seeds=None) -> PartitionResult:
        """Enqueue one partition request; resolves to the same
        :class:`PartitionResult` a standalone ``partition(graph, cfg)``
        call with the resolved config would return."""
        if self._queue is None:
            raise RuntimeError("server not started — use `async with server`")
        self.stats["requests"] += 1
        if not self._admissible(graph):
            self.stats["rejected"] += 1
            raise ValueError(
                "graph exceeds the serve ladder's top rung "
                f"({self.cfg.ladder_n}, {self.cfg.ladder_m}) — raise "
                "ServeConfig.ladder_n/ladder_m or partition it standalone"
            )
        cfg = _resolve_cfg(self.cfg.partition, k, trials, seed, trial_seeds)
        req = _Request(graph=graph, cfg=cfg,
                       cfg_key=(cfg.k, cfg.trials, cfg.seed,
                                cfg.trial_seeds),
                       future=asyncio.get_running_loop().create_future(),
                       t_enqueue=time.perf_counter())
        await self._queue.put(req)
        return await req.future

    async def _batch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        inflight: set[asyncio.Task] = set()
        draining = False
        while not draining:
            first = await self._queue.get()
            if first is None:
                break
            batch = [first]
            deadline = loop.time() + self.cfg.window_s
            while len(batch) < self.cfg.max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if nxt is None:  # stop() mid-window: serve the batch, exit
                    draining = True
                    break
                batch.append(nxt)
            groups: dict[tuple, list[_Request]] = {}
            for r in batch:
                groups.setdefault(r.cfg_key, []).append(r)
            # dispatch WITHOUT awaiting: the single-worker executor
            # serializes device work while this loop keeps coalescing the
            # next window on top of it
            for reqs in groups.values():
                t = asyncio.create_task(
                    self._dispatch_group(reqs[0].cfg, reqs))
                inflight.add(t)
                t.add_done_callback(inflight.discard)
        if inflight:
            await asyncio.gather(*inflight)

    async def _dispatch_group(self, cfg: PartitionConfig,
                              reqs: list[_Request]) -> None:
        try:
            results, log = await asyncio.get_running_loop().run_in_executor(
                self._pool, self._dispatch, cfg, reqs)
        except Exception as e:  # noqa: BLE001 — routed to callers
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(
                        RuntimeError(f"dispatch failed: {e}"))
        else:
            # all stats/log mutation happens HERE, on the event-loop
            # thread — metrics() can iterate them concurrently without
            # racing the worker
            self.stats["dispatches"] += 1
            hist = self.stats["occupancy_hist"]
            for b in log["buckets"]:
                self.stats["buckets"] += 1
                self.stats["filler_lanes"] += b["lanes"] - b["real"]
                hist[b["real"]] = hist.get(b["real"], 0) + 1
            self.dispatch_log.append(log)
            t_done = time.perf_counter()
            for r, res in zip(reqs, results):
                if r.future.done():  # caller gave up (cancelled/timed out)
                    continue
                self.stats["responses"] += 1
                self.stats["latency_s"].append(t_done - r.t_enqueue)
                r.future.set_result(res)

    def _dispatch(self, cfg: PartitionConfig, reqs: list[_Request]):
        """One coalesced fleet run (worker thread): assemble -> stacked
        fleet -> route.  Request order within the group is preserved.
        Returns (results, log record); the caller applies the record to
        the server's stats so this thread never touches shared state."""
        asm = gr.BucketAssembler(self.schedule, lanes=self.cfg.lanes)
        for i, r in enumerate(reqs):
            asm.add(i, r.graph)
        buckets = asm.flush()
        fres = partition_fleet_stacked(buckets, cfg, self.schedule)
        log = self._log_record(cfg, buckets, fres, len(reqs))
        return [fres.results[i] for i in range(len(reqs))], log

    @staticmethod
    def _log_record(cfg, buckets, fres, nreq) -> dict:
        """Signature-accounting record for one stacked-fleet run."""
        return {
            "k": cfg.k, "trials": cfg.trials, "backend": cfg.backend,
            "c_finest": cfg.c_finest, "c_coarse": cfg.c_coarse,
            "requests": nreq,
            "buckets": [
                {
                    "capacity": list(sb.capacity), "lanes": len(sb.tags),
                    "real": sum(t is not None for t in sb.tags),
                    # caller paddings of the real lanes: differing values
                    # prove the bucket mixed genuinely different graphs
                    "member_n_max": [nm for t, nm in zip(sb.tags,
                                                         sb.orig_n_max)
                                     if t is not None],
                    "levels": fb.levels,
                    "level_stats": [
                        {kk: st[kk] for kk in ("level", "n_max", "m_max",
                                               "ell_width") if kk in st}
                        for st in fb.level_stats
                    ],
                }
                for sb, fb in zip(buckets, fres.buckets)
            ],
        }

    # -- warm-start subsystem ---------------------------------------------

    def warmup(self, shapes, ks=None, trials=None, seed=None,
               compositions: str = "subsets") -> dict:
        """Explicit AOT pass: precompile the (rung, k) signature grid.

        ``shapes`` is a list of representative graphs spanning the
        workload's shape families; for each (k, T) in the grid, they are
        assembled into ``lanes``-wide buckets on the pinned ladder and
        run through the complete fleet path — compiling (and persisting,
        when the compile cache is enabled) every executable the same
        workload will hit at serve time.

        A bucket's coarse-level rung chain follows the per-level batch
        max over its lanes, so it depends on WHICH families share the
        bucket (though not on their multiplicity: duplicate lanes —
        filler included — never move the max).  The default
        ``compositions="subsets"`` therefore dispatches every size-<=
        ``lanes`` subset of each rung's families, covering every lane
        composition a replay of these shapes can produce: afterwards the
        same workload compiles ZERO new executables.  That grid is
        ``sum_s C(F, s)`` dispatches per (rung, k) — fine for the few
        families per rung real workloads have; ``compositions="full"``
        dispatches each rung's full member list once (cheapest, but a
        replay whose buckets mix differently may still compile).

        Call before :meth:`start`; returns executables/cache accounting.
        ``ks``/``trials``/``seed`` default to the server's own partition
        config — the signatures its plain ``submit()`` calls will hit
        (coarsening is seeded, so the rung chain follows the seed).
        """
        from itertools import combinations

        base = self.cfg.partition
        ks = (base.k,) if ks is None else ks
        trials = (base.trials,) if trials is None else trials
        seed = base.seed if seed is None else seed
        shapes = list(shapes)
        _, bucket_map = gr.bucket_graphs(shapes, schedule=self.schedule)
        jobs: list[tuple] = []
        for cap in sorted(bucket_map, reverse=True):
            idxs = bucket_map[cap]
            if compositions == "subsets":
                top = min(self.cfg.lanes, len(idxs))
                jobs += [c for s in range(1, top + 1)
                         for c in combinations(idxs, s)]
            elif compositions == "full":
                jobs.append(tuple(idxs))
            else:
                raise ValueError(
                    f"compositions must be 'subsets' or 'full', got "
                    f"{compositions!r}")

        stats = cache_stats()
        before_cache = stats.snapshot()
        before_exec = uncoarsen_level_fleet._cache_size()
        t0 = time.perf_counter()
        for k in ks:
            for t in trials:
                cfg = _resolve_cfg(self.cfg.partition, k, t, seed, None)
                for sub in jobs:
                    asm = gr.BucketAssembler(self.schedule,
                                             lanes=self.cfg.lanes)
                    for i in sub:
                        asm.add(i, shapes[i])
                    buckets = asm.flush()
                    fres = partition_fleet_stacked(buckets, cfg,
                                                   self.schedule)
                    self.warmup_log.append(
                        self._log_record(cfg, buckets, fres, len(sub)))
        return {
            "warmup_s": time.perf_counter() - t0,
            "signatures": [(k, t) for k in ks for t in trials],
            "new_executables": uncoarsen_level_fleet._cache_size()
            - before_exec,
            "cache_events": CompileCacheStats.delta(before_cache,
                                                    stats.snapshot()),
        }

    # -- reporting ---------------------------------------------------------

    def metrics(self) -> dict:
        """Service-side metrics snapshot (latency, occupancy, compiles)."""
        import numpy as np

        lat = sorted(self.stats["latency_s"])
        occ = self.stats["occupancy_hist"]
        occ_total = sum(occ.values())
        return {
            "requests": self.stats["requests"],
            "responses": self.stats["responses"],
            "rejected": self.stats["rejected"],
            "dispatches": self.stats["dispatches"],
            "buckets": self.stats["buckets"],
            "filler_lanes": self.stats["filler_lanes"],
            "occupancy_hist": {str(kk): vv for kk, vv in sorted(occ.items())},
            "mean_occupancy": (
                sum(kk * vv for kk, vv in occ.items()) / occ_total
                if occ_total else 0.0
            ),
            "p50_latency_ms": 1e3 * float(np.percentile(lat, 50)) if lat
            else 0.0,
            "p95_latency_ms": 1e3 * float(np.percentile(lat, 95)) if lat
            else 0.0,
            "uncoarsen_executables": uncoarsen_level_fleet._cache_size(),
            "compile_cache": cache_stats().snapshot(),
        }


def serve_signatures(dispatch_log) -> set:
    """Distinct ``uncoarsen_level_fleet`` compile signatures a serve run
    must have hit — the §10 ``_fleet_signatures`` counting rule lifted to
    the dispatch log: (lanes, T, fine rung, coarse rung, c, ell width, k,
    backend).  With the fixed-lanes discipline this collapses to one
    signature per (rung, k): lanes and T never vary within a server."""
    sigs = set()
    for d in dispatch_log:
        for b in d["buckets"]:
            sts = b["level_stats"]
            for j, st in enumerate(sts):
                nc = st["n_max"] if j == 0 else sts[j - 1]["n_max"]
                c = d["c_finest"] if st["level"] == 0 else d["c_coarse"]
                md = st.get("ell_width") if d["backend"] == "ell" else None
                sigs.add((b["lanes"], d["trials"], st["n_max"], st["m_max"],
                          nc, c, md, d["k"], d["backend"]))
    return sigs
