"""Cell builder: (arch x shape x mesh) -> jit-able step + specs + shardings.

This is the single place where the dry-run, the trainer, and the server get
their step functions, so the compiled artifact is identical across them.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import Arch
from repro.launch import sharding as sh
from repro.launch.mesh import dp_axes
from repro.models import transformer as tf
from repro.models.gnn import graphsage, meshgraphnet, nequip, schnet
from repro.models.gnn.common import GraphBatch
from repro.models.recsys import fm as fm_lib
from repro.optim import adamw


class Cell(NamedTuple):
    step_fn: Any          # callable(*args)
    args: tuple           # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate: tuple
    meta: dict            # model_flops, param_count, kind, notes


class SkippedCell(Exception):
    pass


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


GNN_MODULES = {
    "schnet": schnet,
    "nequip": nequip,
    "graphsage-reddit": graphsage,
    "meshgraphnet": meshgraphnet,
}


# ---------------------------------------------------------------------------
# model-flops estimates (roofline "useful flops")
# ---------------------------------------------------------------------------

def lm_model_flops(cfg: tf.LMConfig, shape) -> float:
    n_active = cfg.active_param_count()
    if shape["kind"] == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * n_active * tokens
    if shape["kind"] == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape["batch"]
    attn = (2.0 * shape["batch"] * shape["seq"] * cfg.n_layers
            * cfg.n_heads * cfg.qk_dim * 2)
    return 2.0 * n_active * tokens + attn


def gnn_model_flops(arch_id, cfg, shape) -> float:
    n, e = shape.get("n_nodes", shape.get("pad_nodes", 0)), shape.get(
        "n_edges", shape.get("pad_edges", 0))
    if arch_id == "graphsage-reddit":
        d = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
        fwd = sum(2 * n * d[i] * d[i + 1] * 2 + e * d[i]
                  for i in range(cfg.n_layers))
    elif arch_id == "schnet":
        d, r = cfg.d_hidden, cfg.n_rbf
        per = 2 * e * (r * d + d * d) + 2 * n * 3 * d * d + e * d
        fwd = cfg.n_interactions * per + 2 * n * d * (d // 2)
    elif arch_id == "nequip":
        c, r = cfg.d_hidden, cfg.n_rbf
        per = (2 * e * (r * 32 + 32 * cfg.n_paths * c)
               + e * c * (1 + 3 * 4 + 9 * 2) * 2
               + 2 * n * (2 * c * c + 3 * c * c + 9 * c * c))
        fwd = cfg.n_layers * per + 2 * n * c * 16
    else:  # meshgraphnet
        d = cfg.d_hidden
        per = 2 * e * (3 * d * d + d * d) + 2 * n * (2 * d * d + d * d)
        fwd = cfg.n_layers * per + 2 * n * (cfg.d_in + cfg.d_out) * d
    return 3.0 * fwd  # fwd + bwd ~ 3x forward


def fm_model_flops(cfg, shape) -> float:
    if shape["kind"] == "retrieval":
        return 2.0 * shape["n_candidates"] * cfg.embed_dim
    mult = 6.0 if shape["kind"] == "train" else 2.0
    return mult * shape["batch"] * cfg.n_fields * cfg.embed_dim


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch: Arch, shape_name: str, mesh, smoke: bool = False,
             tuning: dict | None = None) -> Cell:
    tuning = tuning or {}
    cfg: tf.LMConfig = arch.smoke if smoke else arch.config
    if "config" in tuning:
        cfg = dataclasses.replace(cfg, **tuning["config"])
    shape = arch.shapes[shape_name]
    if shape is None:
        raise SkippedCell(arch.skip_notes.get(shape_name, "skipped"))
    kind = shape["kind"]
    batch, seq = shape["batch"], shape["seq"]
    dt = jnp.dtype(cfg.dtype)

    params_sds = jax.eval_shape(partial(tf.init_params, cfg),
                                jax.random.key(0))
    zero1 = tuning.get("zero1", False)
    p_sh = (sh.lm_param_sharding_zero1(mesh, params_sds) if zero1
            else sh.lm_param_sharding(mesh, params_sds))
    dp = dp_axes(mesh)
    meta = {
        "kind": kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "model_flops": lm_model_flops(cfg, shape),
        "tokens": batch * (seq if kind != "decode" else 1),
    }

    if kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_sds = jax.eval_shape(adamw.init_state, params_sds)
        # ZeRO-1: optimizer state (and accumulated grads) keep the 2D FSDP
        # sharding even though params are replicated over 'data'
        grad_sh = sh.lm_param_sharding(mesh, params_sds)
        o_sh = sh.opt_sharding_like(grad_sh if zero1 else p_sh, mesh)
        b_sh = sh.lm_batch_sharding(mesh)
        batch_sds = {
            "tokens": _sds((batch, seq), jnp.int32),
            "labels": _sds((batch, seq), jnp.int32),
        }
        # gradient accumulation: cap per-microbatch activation working set
        # (~f32 x ~8 live (tokens/dev, width) buffers) near 8 GiB/device.
        # MoE dispatch widens the live set by the active expert width.
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        per_dev = max(batch // dp_size, 1)
        eff_d = cfg.d_model
        if cfg.moe:
            eff_d = max(eff_d, (cfg.top_k + cfg.n_shared) * cfg.d_expert)
        live = per_dev * seq * eff_d * 4 * 8
        microbatches = 1
        budget = tuning.get("mb_budget", 8e9)
        while (live / microbatches > budget and microbatches < per_dev
               and batch % (dp_size * microbatches * 2) == 0):
            microbatches *= 2
        microbatches = tuning.get("microbatches", microbatches)
        meta["microbatches"] = microbatches

        def train_step(params, opt_state, b):
            mb = microbatches

            def constrain_grads(g):
                # ZeRO-1: reduce-scatter each microbatch's grads into the
                # 2D sharding (instead of keeping them param-replicated)
                if not zero1:
                    return g
                return jax.tree.map(
                    jax.lax.with_sharding_constraint, g, grad_sh)

            def one(p, tb):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda pp: tf.loss_fn(cfg, pp, tb), has_aux=True)(p)
                return loss, constrain_grads(grads)

            if mb == 1:
                loss, grads = one(params, b)
            else:
                bt = {k: v.reshape(mb, batch // mb, seq)
                      for k, v in b.items()}

                def acc(carry, tb):
                    loss_sum, g = carry
                    li, gi = one(params, tb)
                    g = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g, gi)
                    return (loss_sum + li, constrain_grads(g)), None

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                g0 = constrain_grads(g0)
                (loss_sum, grads), _ = jax.lax.scan(
                    acc, (jnp.float32(0), g0), bt)
                loss = loss_sum / mb
                grads = jax.tree.map(lambda x: x / mb, grads)
            params, opt_state, om = adamw.apply_updates(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, {"loss": loss, **om}

        return Cell(
            step_fn=train_step,
            args=(params_sds, opt_sds, batch_sds),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate=(0, 1),
            meta=meta,
        )

    if kind == "prefill":
        tok_sds = _sds((batch, seq), jnp.int32)
        cache_sds = jax.eval_shape(
            lambda p, t: tf.prefill(cfg, p, t)[1], params_sds, tok_sds)
        c_sh = sh.lm_cache_sharding(mesh, cache_sds, batch)

        def prefill_step(params, tokens):
            return tf.prefill(cfg, params, tokens)

        return Cell(
            step_fn=prefill_step,
            args=(params_sds, tok_sds),
            in_shardings=(p_sh, NamedSharding(mesh, P(dp, None))),
            out_shardings=(sh.lm_logits_sharding(mesh), c_sh),
            donate=(),
            meta=meta,
        )

    # decode
    cache_sds = jax.eval_shape(partial(tf.init_cache, cfg, batch, seq))
    c_sh = sh.lm_cache_sharding(mesh, cache_sds, batch)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    big_b = batch % dp_size == 0 and batch >= dp_size
    tok_sh = NamedSharding(mesh, P(dp) if big_b else P())
    logit_sh = NamedSharding(mesh, P(dp if big_b else None, "model"))

    def serve_step(params, cache, tokens):
        # decode against a (statically) almost-full cache
        cache = dict(cache, len=jnp.asarray(seq - 1, jnp.int32))
        return tf.decode_step(cfg, params, cache, tokens)

    return Cell(
        step_fn=serve_step,
        args=(params_sds, cache_sds, _sds((batch,), jnp.int32)),
        in_shardings=(p_sh, c_sh, tok_sh),
        out_shardings=(logit_sh, c_sh),
        donate=(1,),
        meta=meta,
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_shape_config(arch: Arch, shape_name: str, smoke: bool):
    cfg = arch.smoke if smoke else arch.config
    shape = arch.shapes[shape_name]
    if arch.id == "graphsage-reddit":
        cfg = dataclasses.replace(cfg, d_in=shape["d_feat"])
    elif arch.id == "meshgraphnet":
        cfg = dataclasses.replace(cfg, d_in=shape["d_feat"])
    return cfg, shape


def _pad512(x: int) -> int:
    """Mesh-divisible padding (512 = the largest mesh device count); the
    models' ghost-index convention makes padded rows inert."""
    return ((x + 511) // 512) * 512


def _gnn_batch_sds(arch_id: str, shape) -> dict:
    n = _pad512(shape.get("n_nodes", shape.get("pad_nodes")))
    e = _pad512(shape.get("n_edges", shape.get("pad_edges")))
    g = shape["n_graphs"]
    d_feat = shape["d_feat"]
    molecular = arch_id in ("schnet", "nequip")
    b = {
        "node_feat": _sds((n, 1 if molecular else d_feat), jnp.float32),
        "senders": _sds((e,), jnp.int32),
        "receivers": _sds((e,), jnp.int32),
        "pos": _sds((n, 3), jnp.float32),
        "graph_id": _sds((n,), jnp.int32),
    }
    if molecular:
        b["energy"] = _sds((g,), jnp.float32)
    elif arch_id == "graphsage-reddit":
        b["labels"] = _sds((n,), jnp.int32)
    else:
        b["target"] = _sds((n, 2), jnp.float32)
    return b


def _gnn_cell(arch: Arch, shape_name: str, mesh, smoke: bool = False,
              tuning: dict | None = None) -> Cell:
    tuning = tuning or {}
    if tuning.get("mode") == "partitioned":
        from repro.launch.gnn_partitioned import partitioned_gnn_cell

        return partitioned_gnn_cell(arch, shape_name, mesh, smoke, tuning)
    cfg, shape = _gnn_shape_config(arch, shape_name, smoke)
    mod = GNN_MODULES[arch.id]
    n_graphs = shape["n_graphs"]
    params_sds = jax.eval_shape(partial(mod.init_params, cfg),
                                jax.random.key(0))
    p_sh = sh.gnn_param_sharding(mesh, params_sds)
    opt_cfg = adamw.AdamWConfig()
    opt_sds = jax.eval_shape(adamw.init_state, params_sds)
    o_sh = sh.opt_sharding_like(p_sh, mesh)
    batch_sds = _gnn_batch_sds(arch.id, shape)
    b_sh = sh.gnn_batch_sharding(mesh, batch_sds)

    def loss(params, b):
        graph = GraphBatch(
            node_feat=b["node_feat"], senders=b["senders"],
            receivers=b["receivers"], edge_feat=None, pos=b["pos"],
            graph_id=b["graph_id"], n_graphs=n_graphs)
        if arch.id in ("schnet", "nequip"):
            payload = {"graph": graph, "energy": b["energy"]}
        elif arch.id == "graphsage-reddit":
            payload = {"graph": graph, "labels": b["labels"]}
        else:
            payload = {"graph": graph, "target": b["target"]}
        return mod.loss_fn(cfg, params, payload)

    def train_step(params, opt_state, b):
        (l, metrics), grads = jax.value_and_grad(
            loss, has_aux=True)(params, b)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": l, **om}

    return Cell(
        step_fn=train_step,
        args=(params_sds, opt_sds, batch_sds),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate=(0, 1),
        meta={
            "kind": "train",
            "param_count": cfg.param_count(),
            "active_param_count": cfg.param_count(),
            "model_flops": gnn_model_flops(arch.id, cfg, shape),
            "tokens": shape.get("n_nodes", shape.get("pad_nodes")),
        },
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _fm_cell(arch: Arch, shape_name: str, mesh, smoke: bool = False) -> Cell:
    cfg: fm_lib.FMConfig = arch.smoke if smoke else arch.config
    shape = arch.shapes[shape_name]
    kind = shape["kind"]
    params_sds = jax.eval_shape(partial(fm_lib.init_params, cfg),
                                jax.random.key(0))
    p_sh = sh.fm_param_sharding(mesh, params_sds)
    dp = dp_axes(mesh)
    all_axes = tuple(mesh.axis_names)
    meta = {
        "kind": kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.param_count(),
        "model_flops": fm_model_flops(cfg, shape),
        "tokens": shape.get("batch", 1),
    }

    if kind == "train":
        b = shape["batch"]
        opt_cfg = adamw.AdamWConfig()
        opt_sds = jax.eval_shape(adamw.init_state, params_sds)
        o_sh = sh.opt_sharding_like(p_sh, mesh)
        batch_sds = {"ids": _sds((b, cfg.n_fields), jnp.int32),
                     "labels": _sds((b,), jnp.float32)}

        def train_step(params, opt_state, batch):
            (l, metrics), grads = jax.value_and_grad(
                lambda p: fm_lib.loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt_state, om = adamw.apply_updates(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, {"loss": l, **om}

        return Cell(
            step_fn=train_step,
            args=(params_sds, opt_sds, batch_sds),
            in_shardings=(p_sh, o_sh, sh.fm_batch_sharding(mesh)),
            out_shardings=(p_sh, o_sh, None),
            donate=(0, 1),
            meta=meta,
        )

    if kind == "serve":
        b = shape["batch"]

        def serve_step(params, ids):
            return fm_lib.serve(cfg, params, ids)

        return Cell(
            step_fn=serve_step,
            args=(params_sds, _sds((b, cfg.n_fields), jnp.int32)),
            in_shardings=(p_sh, NamedSharding(mesh, P(dp, None))),
            out_shardings=NamedSharding(mesh, P(dp)),
            donate=(),
            meta=meta,
        )

    # retrieval: one query, 1M candidates. 1e6 divides the dp axes (16/32)
    # but not the full 256/512-way mesh, so candidates shard over dp only.
    c = shape["n_candidates"]

    def retrieval_step(params, user_ids, cand_ids):
        return fm_lib.retrieval_scores(cfg, params, user_ids, cand_ids)

    return Cell(
        step_fn=retrieval_step,
        args=(params_sds, _sds((1, cfg.n_fields - 1), jnp.int32),
              _sds((c,), jnp.int32)),
        in_shardings=(p_sh, NamedSharding(mesh, P()),
                      NamedSharding(mesh, P(dp))),
        out_shardings=NamedSharding(mesh, P(dp)),
        donate=(),
        meta=meta,
    )


def smoke_shapes(arch: Arch) -> dict:
    """Reduced shapes for CPU smoke tests (one step per shape kind)."""
    if arch.family == "lm":
        return {
            "train_4k": {"kind": "train", "seq": 64, "batch": 2},
            "prefill_32k": {"kind": "prefill", "seq": 64, "batch": 2},
            "decode_32k": {"kind": "decode", "seq": 64, "batch": 2},
            "long_500k": (None if arch.shapes.get("long_500k") is None else
                          {"kind": "decode", "seq": 128, "batch": 1}),
        }
    if arch.family == "gnn":
        return {
            "full_graph_sm": {"kind": "train", "n_nodes": 128, "n_edges": 512,
                              "d_feat": 16, "n_graphs": 1},
            "minibatch_lg": {"kind": "train", "pad_nodes": 256,
                             "pad_edges": 512, "d_feat": 16, "n_graphs": 1,
                             "batch_nodes": 16, "fanout": (5, 5),
                             "full_nodes": 0, "full_edges": 0},
            "ogb_products": {"kind": "train", "n_nodes": 256, "n_edges": 1024,
                             "d_feat": 16, "n_graphs": 1},
            "molecule": {"kind": "train", "n_nodes": 4 * 10, "n_edges": 4 * 32,
                         "d_feat": 16, "n_graphs": 4, "atoms": 10},
        }
    return {
        "train_batch": {"kind": "train", "batch": 64},
        "serve_p99": {"kind": "serve", "batch": 16},
        "serve_bulk": {"kind": "serve", "batch": 128},
        "retrieval_cand": {"kind": "retrieval", "batch": 1,
                           "n_candidates": 256},
    }


def materialize(args, seed: int = 0):
    """Turn ShapeDtypeStruct trees into runnable arrays (smoke tests)."""
    key = jax.random.key(seed)

    def one(x):
        if not hasattr(x, "dtype"):
            return x
        if jnp.issubdtype(x.dtype, jnp.integer):
            return jnp.zeros(x.shape, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return (jax.random.normal(key, x.shape, jnp.float32) * 0.02
                    ).astype(x.dtype)
        return jnp.zeros(x.shape, x.dtype)

    return jax.tree.map(one, args)


def materialize_cell(cell: Cell, seed: int = 0):
    """Cell-aware materialization: optimizer state must be *valid* (zero
    moments), not random — sqrt(random nu) is NaN."""
    args = list(materialize(cell.args, seed))
    if cell.meta["kind"] == "train":
        args[1] = adamw.init_state(args[0])
    return tuple(args)


def build_cell(arch: Arch, shape_name: str, mesh, smoke: bool = False,
               tuning: dict | None = None) -> Cell:
    """``tuning`` carries §Perf hillclimb knobs (microbatches, config
    overrides, distribution mode) without touching the baseline configs."""
    if smoke:
        arch = dataclasses.replace(arch, shapes=smoke_shapes(arch))
    if shape_name not in arch.shapes:
        raise KeyError(f"{arch.id} has no shape {shape_name}")
    if arch.shapes[shape_name] is None:
        raise SkippedCell(arch.skip_notes.get(shape_name, "skipped"))
    if arch.family == "lm":
        return _lm_cell(arch, shape_name, mesh, smoke, tuning)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape_name, mesh, smoke, tuning)
    if arch.family == "recsys":
        return _fm_cell(arch, shape_name, mesh, smoke)
    raise ValueError(arch.family)
