"""Serving CLI: replay a partition request stream through PartitionServer.

A workload spec names graph families, a k mix, an arrival rate, and a
request count; the CLI generates the (seeded, deterministic) request
stream, replays it through an in-process :class:`PartitionServer` with
simulated arrival times, and reports latency / throughput / occupancy.

    PYTHONPATH=src python -m repro.launch.serve_cli \
        --families grid:16 grid:15 grid:8 --ks 4,8 --count 24 \
        --rate 500 --window-ms 2 --lanes 2 --warmup

    PYTHONPATH=src python -m repro.launch.serve_cli --workload spec.json

Spec JSON mirrors the flags::

    {"families": [{"graph": "grid", "size": 16, "weight": 2},
                  {"graph": "grid", "size": 8}],
     "ks": [4, 8], "count": 24, "rate_rps": 500.0,
     "trials": 1, "seed": 0}
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time

import numpy as np

from repro.core.partition import PartitionConfig
from repro.launch.partition_cli import _make_graph, _parse_fleet_spec
from repro.launch.partition_serve import (
    PartitionServer, ServeConfig, serve_signatures,
)


def build_workload(spec: dict) -> list[dict]:
    """Materialize a spec into a deterministic request list.

    Each request: ``{"t": arrival offset (s), "graph": Graph, "k": int,
    "trials": int, "family": label}``.  Families are sampled by weight and
    arrival gaps are exponential at ``rate_rps``, both from one seeded
    generator — the same spec always yields the same stream.  k cycles
    round-robin through the mix so every replay is mixed-k by
    construction.
    """
    fams = spec.get("families") or [{"graph": "grid", "size": 16}]
    fams = [f if isinstance(f, dict) else {"graph": f[0], "size": f[1]}
            for f in fams]
    ks = list(spec.get("ks") or [8])
    count = int(spec.get("count", 16))
    rate = float(spec.get("rate_rps", 500.0))
    trials = int(spec.get("trials", 1))
    seed = int(spec.get("seed", 0))

    rng = np.random.default_rng(seed)
    weights = np.asarray([float(f.get("weight", 1.0)) for f in fams])
    weights = weights / weights.sum()
    # one Graph instance per family, shared by its requests (the server
    # never mutates request graphs)
    built = [
        _make_graph(f["graph"], int(f["size"]), int(f.get("seed", seed)))
        for f in fams
    ]
    # the label keys verify/warmup dedup, so it must be unique per distinct
    # graph: families that pin their own seed carry it in the label (two
    # geo:8 entries with different seeds are different graphs)
    labels = [
        f"{f['graph']}:{f['size']}" + (f":{f['seed']}" if "seed" in f
                                       else "")
        for f in fams
    ]
    reqs = []
    t = 0.0
    for i in range(count):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        fi = int(rng.choice(len(fams), p=weights))
        reqs.append({
            "t": t,
            "graph": built[fi],
            "k": ks[i % len(ks)],
            "trials": trials,
            "family": labels[fi],
        })
    return reqs


def workload_shapes(workload: list[dict]):
    """One representative graph per distinct family — the warmup grid's
    shape axis."""
    seen, shapes = set(), []
    for r in workload:
        if r["family"] not in seen:
            seen.add(r["family"])
            shapes.append(r["graph"])
    return shapes


async def replay_workload(server: PartitionServer,
                          workload: list[dict]) -> list[dict]:
    """Fire the request stream at its arrival offsets; gather responses.

    Returns one record per request with the caller-observed latency
    (submit -> response, inclusive of coalescing wait) and the result.
    """

    async def one(req):
        await asyncio.sleep(req["t"])
        t0 = time.perf_counter()
        res = await server.submit(req["graph"], k=req["k"],
                                  trials=req["trials"])
        return {
            "family": req["family"], "k": req["k"], "trials": req["trials"],
            "latency_s": time.perf_counter() - t0,
            "cut": res.cut, "balanced": res.balanced, "result": res,
        }

    async with server:
        return list(await asyncio.gather(*(one(r) for r in workload)))


def run_workload(scfg: ServeConfig, spec: dict, *, warmup: bool = True,
                 verify: bool = False, workload: "list[dict] | None" = None,
                 ) -> dict:
    """Build, (optionally) warm up, and replay a workload; return a report.

    ``verify=True`` re-runs every distinct (family, k, trials) combination
    through standalone ``partition()`` and asserts each coalesced response
    is bit-identical — the serving correctness contract.  ``workload``
    passes a stream already built from ``spec`` (callers that sized the
    ladder from it) so graphs aren't constructed twice.
    """
    from dataclasses import replace

    from repro.core.partition import partition, uncoarsen_level_fleet

    if workload is None:
        workload = build_workload(spec)
    server = PartitionServer(scfg)
    report = {"spec": {kk: vv for kk, vv in spec.items()
                       if kk != "families"} |
              {"families": [f"{f['graph']}:{f['size']}" if isinstance(f, dict)
                            else f"{f[0]}:{f[1]}"
                            for f in (spec.get("families") or [])]}}
    if warmup:
        report["warmup"] = {
            kk: vv for kk, vv in server.warmup(
                workload_shapes(workload),
                ks=sorted({r["k"] for r in workload}),
                trials=sorted({r["trials"] for r in workload}),
                seed=scfg.partition.seed,
            ).items() if kk != "signatures"
        }
    execs0 = uncoarsen_level_fleet._cache_size()
    t0 = time.perf_counter()
    records = asyncio.run(replay_workload(server, workload))
    wall = time.perf_counter() - t0
    report["post_warmup_new_executables" if warmup
           else "new_executables"] = (
        uncoarsen_level_fleet._cache_size() - execs0
    )

    if verify:
        solo_cache: dict = {}
        for rec in records:
            key = (rec["family"], rec["k"], rec["trials"])
            if key not in solo_cache:
                g = next(r["graph"] for r in workload
                         if r["family"] == rec["family"])
                solo_cache[key] = partition(
                    g, replace(scfg.partition, k=rec["k"],
                               trials=rec["trials"]))
            solo = solo_cache[key]
            same = (rec["cut"] == solo.cut
                    and rec["balanced"] == solo.balanced
                    and np.array_equal(np.asarray(rec["result"].parts),
                                       np.asarray(solo.parts)))
            if not same:
                raise AssertionError(
                    f"serve response diverged from standalone partition() "
                    f"for {key}: serve cut {rec['cut']} vs solo {solo.cut}"
                )
        report["bit_identical"] = True

    lats = sorted(r["latency_s"] for r in records)
    report |= {
        "requests": len(records),
        "wall_s": wall,
        "throughput_rps": len(records) / max(wall, 1e-9),
        "p50_latency_ms": 1e3 * float(np.percentile(lats, 50)),
        "p95_latency_ms": 1e3 * float(np.percentile(lats, 95)),
        "per_request": [
            {kk: r[kk] for kk in ("family", "k", "trials", "cut",
                                  "balanced")}
            | {"latency_ms": 1e3 * r["latency_s"]}
            for r in records
        ],
        "server": server.metrics(),
        "serve_signatures": len(serve_signatures(server.dispatch_log)),
        # per-dispatch bucket records (lanes/real/member_n_max/levels) —
        # the bench's mixed-occupancy evidence
        "dispatch_buckets": [d["buckets"] for d in server.dispatch_log],
    }
    if warmup:
        wsigs = serve_signatures(server.warmup_log)
        report["warmup_signatures"] = len(wsigs)
        report["replay_covered_by_warmup"] = (
            serve_signatures(server.dispatch_log) <= wsigs
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    help="workload spec JSON path (overrides the stream "
                         "flags below)")
    ap.add_argument("--families", nargs="+", default=["grid:16", "grid:8"],
                    metavar="SPEC", help="graph families, name[:size[:seed]]")
    ap.add_argument("--ks", default="8", help="comma-separated k mix")
    ap.add_argument("--count", type=int, default=16)
    ap.add_argument("--rate", type=float, default=500.0,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="coalescing window")
    ap.add_argument("--lanes", type=int, default=2,
                    help="fixed batch width per dispatched bucket")
    ap.add_argument("--ladder-n", type=int, default=None,
                    help="serve ladder top rung, vertices (default: fit "
                         "the workload's largest family)")
    ap.add_argument("--ladder-m", type=int, default=None)
    ap.add_argument("--backend", default="dense",
                    choices=["dense", "sorted", "ell"])
    ap.add_argument("--coarse-target", type=int, default=4096)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the AOT (rung, k) warmup pass")
    ap.add_argument("--verify", action="store_true",
                    help="assert every response is bit-identical to a "
                         "standalone partition() run")
    ap.add_argument("--compile-cache", default=None,
                    help="JAX persistent compilation cache directory")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    if args.workload:
        with open(args.workload) as f:
            spec = json.load(f)
    else:
        fams = [_parse_fleet_spec(s, 16, args.seed) for s in args.families]
        spec = {
            "families": [{"graph": kk, "size": ss, "seed": sd}
                         for kk, ss, sd in fams],
            "ks": [int(x) for x in args.ks.split(",")],
            "count": args.count, "rate_rps": args.rate,
            "trials": args.trials, "seed": args.seed,
        }

    workload = build_workload(spec)
    if args.ladder_n is None or args.ladder_m is None:
        shapes = workload_shapes(workload)
        args.ladder_n = args.ladder_n or max(g.n_max for g in shapes)
        args.ladder_m = args.ladder_m or max(g.m_max for g in shapes)

    pcfg = PartitionConfig(backend=args.backend,
                           coarse_target=args.coarse_target, seed=args.seed)
    scfg = ServeConfig(ladder_n=args.ladder_n, ladder_m=args.ladder_m,
                       window_s=args.window_ms / 1e3, lanes=args.lanes,
                       partition=pcfg, compile_cache=args.compile_cache)
    try:
        report = run_workload(scfg, spec, warmup=not args.no_warmup,
                              verify=args.verify, workload=workload)
    except AssertionError as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 1
    summary = {kk: vv for kk, vv in report.items()
               if kk not in ("per_request", "dispatch_buckets")}
    print(json.dumps(summary, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"-> {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
