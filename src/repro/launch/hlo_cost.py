"""Loop-aware cost model over compiled (post-SPMD, post-fusion) HLO text.

Why: XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
for scan-over-layers models that undercounts flops by the layer count (we
measured gemma3 L=2/4/8 all reporting identical flops).  This walker
multiplies each computation's cost by its loop trip count (read from
``backend_config={"known_trip_count":{"n":...}}``).

Counting rules:
  flops          — dot ops: 2 * prod(out_shape) * prod(contracting dims)
                   (operand shapes are inline in HLO text); elementwise
                   arithmetic: 1 flop/output element.  Descends into
                   fusion bodies (dots can live inside fusions).
  transcendental — exp/log/tanh/... 1/element.
  bytes          — operand + output bytes of *top-level* ops only: in
                   post-fusion HLO a fusion's operands/outputs are the real
                   HBM traffic; fusion internals live in registers/VMEM.
                   tuple/gte/bitcast/parameter/constant are free.
  collectives    — output bytes per op kind (all-reduce, all-gather,
                   reduce-scatter, all-to-all, collective-permute), trip-
                   count multiplied like everything else.

The numbers are estimates (documented in EXPERIMENTS.md §Roofline), cross-
validated against cost_analysis on loop-free programs and against analytic
6*N*D model flops.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([a-z0-9\-]+)\((.*)$"
)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder", "power",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "sine", "cosine", "expm1", "log1p", "erf", "atan2",
                   "cbrt"}
_FREE = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
         "after-all", "partition-id", "replica-id", "iota"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _tensor_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def _tensor_elems(type_text: str) -> int:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return 0
    size = 1
    for d in m.group(2).split(","):
        if d:
            size *= int(d)
    return size


def _split_computations(text: str) -> dict:
    comps = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        if cur_name is None:
            # computation headers start at column 0 and end with '{'
            if line[:1] not in ("", " ", "\t") and line.rstrip().endswith("{"):
                m = _COMP_START_RE.match(line.strip())
                if m:
                    cur_name = m.group(1)
                    cur_lines = []
        else:
            if line.strip() == "}":
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(args_text: str):
    """Operand %names inside the first (...) of the op call."""
    depth = 0
    end = len(args_text)
    for i, ch in enumerate(args_text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth <= 0:
                end = i
                break
    return _OPERAND_RE.findall(args_text[:end])


def _dot_flops(result_type: str, args_text: str, types: dict) -> int:
    out_elems = _tensor_elems(result_type)
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", args_text)
    # lhs type: inline (f32[..] %a) or via the symbol table
    lhs_type = None
    inline = _SHAPE_RE.search(args_text.split(",")[0])
    if inline:
        lhs_type = inline.group(0)
    else:
        names = _operand_names(args_text)
        if names:
            lhs_type = types.get(names[0])
    if lc is None or lhs_type is None:
        return 2 * out_elems  # degenerate
    m = _SHAPE_RE.search(lhs_type)
    lhs_dims = [int(d) for d in m.group(2).split(",") if d] if m else []
    contract = 1
    for idx in lc.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2 * out_elems * contract


class HloCost:
    def __init__(self, text: str):
        self.comps = _split_computations(text)
        self._memo = {}
        # entry = computation named ENTRY in original text
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        self.entry = m.group(1) if m else next(iter(self.comps))

    def _called(self, args_text: str):
        """(name, multiplier) pairs for computations invoked by an op."""
        out = []
        mb = re.search(r"body=%?([\w.\-]+)", args_text)
        if mb:
            trip = 1
            mt = _TRIP_RE.search(args_text)
            if mt:
                trip = int(mt.group(1))
            out.append((mb.group(1), trip))
            mc = re.search(r"condition=%?([\w.\-]+)", args_text)
            if mc:
                out.append((mc.group(1), trip))
            return out
        mf = re.search(r"calls=%?([\w.\-]+)", args_text)
        if mf:
            out.append((mf.group(1), 1))
        mta = re.search(r"to_apply=%?([\w.\-]+)", args_text)
        if mta:
            out.append((mta.group(1), 1))
        mbr = re.search(r"branch_computations=\{([^}]*)\}", args_text)
        if mbr:
            for name in mbr.group(1).split(","):
                out.append((name.strip().lstrip("%"), 1))
        return out

    def _types(self, comp: str) -> dict:
        types = {}
        for line in self.comps.get(comp, ()):
            m = _OPLINE_RE.match(line)
            if m:
                types[m.group(1)] = m.group(2)
        return types

    def cost(self, comp: str | None = None, _inside_fusion=False) -> dict:
        comp = comp or self.entry
        key = (comp, _inside_fusion)
        if key in self._memo:
            return self._memo[key]
        totals = defaultdict(float)
        types = self._types(comp)
        for line in self.comps.get(comp, ()):
            m = _OPLINE_RE.match(line)
            if not m:
                continue
            _, result_type, op, args = m.groups()
            base = op.replace("-start", "")
            if op.endswith("-done") or op in _FREE:
                continue
            out_bytes = _tensor_bytes(result_type)
            out_elems = _tensor_elems(result_type)
            if base in _COLLECTIVES:
                totals[f"coll_{base}_bytes"] += out_bytes
                totals[f"coll_{base}_count"] += 1
                totals["coll_bytes"] += out_bytes
            if op == "dot":
                totals["flops"] += _dot_flops(result_type, args, types)
            elif op == "convolution":
                totals["flops"] += 2 * out_elems  # not used by our models
            elif op in _TRANSCENDENTAL:
                totals["transcendentals"] += out_elems
                totals["flops"] += out_elems
            elif op in _ELEMENTWISE or op in ("reduce", "reduce-window"):
                totals["flops"] += out_elems
            # bytes: top-level ops only (fusion operands = HBM traffic).
            # In-place/indexed ops touch only the indexed region, not the
            # whole operand (a decode step's cache DUS would otherwise be
            # charged the full multi-GiB cache per layer):
            if not _inside_fusion:
                names = _operand_names(args)
                if op in ("dynamic-slice", "gather"):
                    operand_bytes = out_bytes  # read region == output
                elif op == "dynamic-update-slice":
                    upd = (_tensor_bytes(types.get(names[1], ""))
                           if len(names) > 1 else out_bytes)
                    operand_bytes = upd  # read update; write same region
                    out_bytes = upd
                elif op == "scatter":
                    upd = (_tensor_bytes(types.get(names[2], ""))
                           if len(names) > 2 else out_bytes)
                    operand_bytes = 2 * upd  # read region + updates
                    out_bytes = upd
                else:
                    operand_bytes = sum(
                        _tensor_bytes(types.get(n, "")) for n in names)
                totals["bytes"] += out_bytes + operand_bytes
            # descend
            for name, mult in self._called(args):
                inner_fusion = _inside_fusion or op == "fusion"
                sub = self.cost(name, inner_fusion)
                for k, v in sub.items():
                    totals[k] += mult * v
        result = dict(totals)
        self._memo[key] = result
        return result


def analyze_hlo(text: str) -> dict:
    c = HloCost(text).cost()
    out = {
        "flops": c.get("flops", 0.0),
        "transcendentals": c.get("transcendentals", 0.0),
        "bytes": c.get("bytes", 0.0),
        "collective_bytes": c.get("coll_bytes", 0.0),
        "collectives": {},
    }
    for kind in _COLLECTIVES:
        b = c.get(f"coll_{kind}_bytes", 0.0)
        n = c.get(f"coll_{kind}_count", 0.0)
        if n:
            out["collectives"][kind] = {"bytes": b, "count": n}
    return out
