"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Raw-pytree implementation (no optax).  Optimizer state is kept in f32
regardless of param dtype (mixed-precision training discipline).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"   # cosine | linear | const


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return cfg.lr * warm * decay


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + (
            cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gn, "lr": lr}
