"""int8 error-feedback gradient compression (1-bit-Adam family, 8-bit here).

For data-parallel all-reduce at 1000+ node scale the gradient traffic is the
dominant collective; quantizing to int8 with an error-feedback residual cuts
bytes 4x (vs f32) / 2x (vs bf16) with negligible quality loss.  The
transform is collective-agnostic: compress -> (all-reduce int8 payloads) ->
decompress, with the quantization error carried to the next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error):
    """Returns (payload int8 tree, scales tree, new_error tree)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, g - deq

    flat, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat, flat_e)]
    q = jax.tree.unflatten(treedef, [o[0] for o in outs])
    s = jax.tree.unflatten(treedef, [o[1] for o in outs])
    ne = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return q, s, ne


def decompress(payload, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales)


def compressed_bytes(grads) -> int:
    """int8 payload + f32 scale per tensor."""
    return sum(x.size + 4 for x in jax.tree.leaves(grads))


def raw_bytes(grads) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(grads))
