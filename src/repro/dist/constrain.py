"""Axis-name sharding annotations that degrade to no-ops off-mesh.

Models annotate intermediates with logical axis names::

    x = constrain(x, "batch", None, "model")   # one name per array dim

``"batch"`` is a logical alias for the data-parallel axes of the active
mesh (``("pod", "data")`` when a pod axis exists, else ``("data",)``);
other names are physical mesh axes and are dropped when the mesh lacks
them.  With no active mesh — unit tests, single-host CPU runs — every
call returns its input unchanged, so the zoo stays runnable anywhere.

The active mesh is either the innermost ``with mesh:`` scope (JAX's
thread-local mesh context) or an explicit :func:`constraint_mesh` scope,
which also works around jit boundaries where the context manager does not
reach.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH_STACK: list = []


@contextlib.contextmanager
def constraint_mesh(mesh):
    """Explicitly scope the mesh :func:`constrain` resolves against."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    """The mesh constrain() resolves against, or None."""
    if _MESH_STACK:
        return _MESH_STACK[-1]
    try:  # `with mesh:` scope (thread-local physical mesh)
        mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if not mesh.empty:
            return mesh
    except AttributeError:
        pass
    return None


def _resolve(axis, mesh_axes):
    if axis is None:
        return None
    if axis == "batch":
        present = tuple(a for a in ("pod", "data") if a in mesh_axes)
        return present if present else None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh_axes)
        return kept if kept else None
    return axis if axis in mesh_axes else None


def constrain(x, *axes):
    """``with_sharding_constraint`` by logical axis names; no-op off-mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1 or len(axes) != x.ndim:
        return x
    names = set(mesh.axis_names)
    spec = P(*(_resolve(a, names) for a in axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
