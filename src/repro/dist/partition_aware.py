"""Partition-aware device layout: the Jet partitioner as a communication
planner for distributed GNN training.

``plan_from_partition`` turns a k-way partition into a :class:`CommPlan`:
each device owns a contiguous block of vertices (``perm`` gives the
device-block order), edges live on their receiver's device, and the plan
records which vertices must be exported as halo features each layer.
``naive_plan`` is the strawman — contiguous vertex blocks in input order —
whose per-layer cost is a full-node all-gather plus all-reduce.

Collective bytes per message-passing layer (see launch/gnn_partitioned.py):
    naive       : N*F (gather) + N*F (reduce)  = 2*N*F
    partitioned : halo_fraction * N * F        (one boundary gather)
so the partitioner's cut quality IS the communication bill.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graph import Graph, graph_to_host


@dataclass(frozen=True)
class CommPlan:
    """Device layout + communication statistics for one partition."""

    k: int                     # number of devices
    n: int                     # vertices
    dev_of: np.ndarray         # (n,) device id per original vertex
    perm: np.ndarray           # (n,) original vertex ids in device-block order
    edges_new: np.ndarray      # (m, 2) directed (sender, receiver), new ids
    local_edge_frac: float     # directed edges with both endpoints co-located
    halo_fraction: float       # unique exported boundary vertices / n
    halo_counts: np.ndarray    # (k,) boundary exports per device


def _plan(n: int, edges_dir: np.ndarray, dev_of: np.ndarray, k: int) -> CommPlan:
    perm = np.argsort(dev_of, kind="stable").astype(np.int64)
    new_id = np.empty(n, np.int64)
    new_id[perm] = np.arange(n)
    src, dst = edges_dir[:, 0], edges_dir[:, 1]
    local = dev_of[src] == dev_of[dst]
    exported = np.unique(src[~local]) if edges_dir.shape[0] else np.empty(0, np.int64)
    halo_counts = np.bincount(dev_of[exported], minlength=k) if exported.size \
        else np.zeros(k, np.int64)
    edges_new = np.stack([new_id[src], new_id[dst]], axis=1)
    return CommPlan(
        k=k,
        n=n,
        dev_of=dev_of,
        perm=perm,
        edges_new=edges_new,
        local_edge_frac=float(local.mean()) if local.size else 1.0,
        halo_fraction=float(exported.size / max(n, 1)),
        halo_counts=halo_counts,
    )


def _directed_edges(g: Graph) -> tuple[int, np.ndarray]:
    n, edges, _, _ = graph_to_host(g)  # (u < v) undirected
    if edges.shape[0] == 0:
        return n, np.zeros((0, 2), np.int64)
    return n, np.concatenate([edges, edges[:, ::-1]]).astype(np.int64)


def plan_from_partition(g: Graph, parts, k: int) -> CommPlan:
    """Layout from a Jet partition: device = part."""
    n, edges_dir = _directed_edges(g)
    dev_of = np.asarray(parts)[:n].astype(np.int64)
    assert dev_of.min() >= 0 and dev_of.max() < k, "partition has ghost parts"
    return _plan(n, edges_dir, dev_of, k)


def naive_plan(g: Graph, k: int) -> CommPlan:
    """Contiguous input-order blocks — the layout you get without a
    partitioner.  Same CommPlan shape, so costs compare directly."""
    n, edges_dir = _directed_edges(g)
    block = (n + k - 1) // k
    dev_of = np.arange(n, dtype=np.int64) // max(block, 1)
    return _plan(n, edges_dir, np.minimum(dev_of, k - 1), k)


def comm_bytes_per_layer(plan: CommPlan, d_feat: int,
                         bytes_per_scalar: int = 4) -> dict:
    """Per-message-passing-layer collective bytes under both schemes."""
    naive = 2 * plan.n * d_feat * bytes_per_scalar
    halo = int(plan.halo_counts.sum()) * d_feat * bytes_per_scalar
    return {
        "naive_allgather": naive,
        "partition_halo": halo,
        "reduction": naive / max(halo, 1),
    }
