"""Distribution substrate: sharding-constraint annotations for the model
zoo (:mod:`repro.dist.constrain`) and the partition-aware device layout
built on the Jet partitioner (:mod:`repro.dist.partition_aware`)."""
