"""LM transformer family: dense GQA, hybrid local/global (Gemma-3 style),
MLA + MoE (DeepSeek-V2 family).  Scan-over-layers with stacked params (one
compiled layer body regardless of depth), optional remat, tied embeddings.

train path   : chunked online-softmax attention (never materializes S x S)
decode path  : KV cache per layer; MLA uses the compressed c_kv cache with
               the absorbed-projection trick (the whole point of MLA).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from repro.dist.constrain import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (
    apply_rope, cross_entropy_loss, dense_init, embed_init, rmsnorm,
    rmsnorm_init,
)


@dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab: int = 1024
    attn_kind: str = "gqa"        # gqa | mla
    window: int = 0               # sliding window size for local layers
    local_ratio: int = 0          # gemma3: 5 (5 local : 1 global)
    kv_lora_rank: int = 0         # MLA
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    moe: bool = False
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0           # >1: group-local dispatch (GShard style)
    aux_loss_coef: float = 0.001
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024
    seq_parallel: bool = False    # Megatron SP: s-sharded residual stream
                                  # (psum -> reduce-scatter at wo/w_down)
    grad_cast: bool = False       # bf16 activation cotangents across layers
    # which serve shapes are valid (long_* skipped for pure full-attention)
    supports_long_context: bool = False

    @property
    def qk_dim(self) -> int:
        return (self.qk_nope_dim + self.qk_rope_dim
                if self.attn_kind == "mla" else self.head_dim)

    def window_pattern(self):
        """(L,) int32 — per-layer sliding window (0 = global)."""
        import numpy as np

        if self.local_ratio <= 0 or self.window <= 0:
            return jnp.zeros((self.n_layers,), jnp.int32)
        pat = np.arange(self.n_layers) % (self.local_ratio + 1)
        return jnp.asarray(
            np.where(pat < self.local_ratio, self.window, 0).astype(np.int32)
        )

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS roofline terms)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        if self.attn_kind == "mla":
            a = (d * self.n_heads * self.qk_dim
                 + d * (self.kv_lora_rank + self.qk_rope_dim)
                 + self.kv_lora_rank * self.n_heads
                 * (self.qk_nope_dim + self.v_head_dim)
                 + self.n_heads * self.v_head_dim * d)
        else:
            a = (d * self.n_heads * self.head_dim
                 + 2 * d * self.n_kv_heads * self.head_dim
                 + self.n_heads * self.head_dim * d)
        if self.moe:
            f = (d * self.n_experts
                 + 3 * self.n_experts * d * self.d_expert
                 + 3 * d * self.n_shared * self.d_expert)
        else:
            f = 3 * d * self.d_ff
        return emb + L * (a + f + 2 * d) + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        inactive = (self.n_experts - self.top_k)
        return full - L * 3 * inactive * d * self.d_expert


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _res_spec(cfg):
    """Residual-stream sharding: sequence-parallel shards S over 'model',
    turning the per-layer output all-reduce into a reduce-scatter."""
    return ("batch", "model", None) if cfg.seq_parallel else (
        "batch", None, None)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _gcb(x, dtype_str):
    return x


def _gcb_fwd(x, dtype_str):
    return x, None


def _gcb_bwd(dtype_str, _, g):
    return (g.astype(dtype_str),)


_gcb.defvjp(_gcb_fwd, _gcb_bwd)


def grad_cast_barrier(x):
    """Identity forward; downcasts the cotangent to the primal dtype.

    The dry-run HLO showed the layer-scan backward moving activation
    cotangents as f32 collectives (1 GiB/layer/device on command-r) even
    though the primal stream is bf16 — this barrier halves backward
    activation communication (standard bf16-gradient-activations
    practice).  Enabled via LMConfig.grad_cast."""
    return _gcb(x, str(x.dtype))


def init_params(cfg: LMConfig, key):
    """Stacked-layer parameter pytree."""
    dt = _dt(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model

    def stack(f, key):
        ks = jax.random.split(key, cfg.n_layers)
        return jax.vmap(f)(ks)

    layer = {}
    if cfg.attn_kind == "mla":
        layer["wq"] = stack(
            lambda k: dense_init(k, d, cfg.n_heads * cfg.qk_dim, dt), keys[0])
        layer["w_dkv"] = stack(
            lambda k: dense_init(k, d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt),
            keys[1])
        layer["w_ukv"] = stack(
            lambda k: dense_init(
                k, cfg.kv_lora_rank,
                cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), dt),
            keys[2])
        layer["wo"] = stack(
            lambda k: dense_init(k, cfg.n_heads * cfg.v_head_dim, d, dt), keys[3])
    else:
        layer["wq"] = stack(
            lambda k: dense_init(k, d, cfg.n_heads * cfg.head_dim, dt), keys[0])
        layer["wk"] = stack(
            lambda k: dense_init(k, d, cfg.n_kv_heads * cfg.head_dim, dt), keys[1])
        layer["wv"] = stack(
            lambda k: dense_init(k, d, cfg.n_kv_heads * cfg.head_dim, dt), keys[2])
        layer["wo"] = stack(
            lambda k: dense_init(k, cfg.n_heads * cfg.head_dim, d, dt), keys[3])
    layer["ln1"] = jnp.ones((cfg.n_layers, d), jnp.float32)
    layer["ln2"] = jnp.ones((cfg.n_layers, d), jnp.float32)
    if cfg.moe:
        layer["moe"] = stack(
            lambda k: moe_lib.moe_init(
                k, d, cfg.d_expert, cfg.n_experts, cfg.n_shared, dt),
            keys[4])
    else:
        layer["w_gate"] = stack(lambda k: dense_init(k, d, cfg.d_ff, dt), keys[4])
        layer["w_up"] = stack(lambda k: dense_init(k, d, cfg.d_ff, dt), keys[5])
        layer["w_down"] = stack(lambda k: dense_init(k, cfg.d_ff, d, dt), keys[6])
    return {
        "embed": embed_init(keys[7], cfg.vocab, d, dt),
        "layers": layer,
        "final_ln": rmsnorm_init(d),
    }


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _gqa_attention(cfg: LMConfig, lp, x, window, positions, return_kv=False):
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, lp["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, lp["wk"]).reshape(b, s, hkv, dh)
    v = jnp.einsum("bsd,de->bse", x, lp["wv"]).reshape(b, s, hkv, dh)
    q = constrain(q.transpose(0, 2, 1, 3), "batch", "model", None, None)
    k = constrain(k.transpose(0, 2, 1, 3), "batch", None, None, None)
    v = constrain(v.transpose(0, 2, 1, 3), "batch", None, None, None)
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k = apply_rope(k, positions[:, None], cfg.rope_theta)
    o = attn.chunked_attention(
        q, k, v, causal=True, window=window, chunk=min(cfg.attn_chunk, s))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    o = constrain(o, "batch", None, "model")
    out = constrain(jnp.einsum("bse,ed->bsd", o, lp["wo"]), *_res_spec(cfg))
    if return_kv:
        return out, (k, v)
    return out


def _mla_attention(cfg: LMConfig, lp, x, window, positions, return_kv=False):
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,de->bse", x, lp["wq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv_full = jnp.einsum("bsd,de->bse", x, lp["w_dkv"])
    ckv, k_rope = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    kv = jnp.einsum("bsr,re->bse", ckv, lp["w_ukv"]).reshape(
        b, s, h, nope + dv)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    q_rope = apply_rope(
        q_rope.transpose(0, 2, 1, 3), positions[:, None], cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, None], positions[:, None], cfg.rope_theta)
    k_rope_b = jnp.broadcast_to(k_rope, (b, h, s, rope))
    qh = constrain(
        jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], -1),
        "batch", "model", None, None)
    kh = constrain(
        jnp.concatenate([k_nope.transpose(0, 2, 1, 3), k_rope_b], -1),
        "batch", "model", None, None)
    vh = constrain(v.transpose(0, 2, 1, 3), "batch", "model", None, None)
    o = attn.chunked_attention(
        qh, kh, vh, causal=True, window=window, chunk=min(cfg.attn_chunk, s))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    o = constrain(o, "batch", None, "model")
    out = constrain(jnp.einsum("bse,ed->bsd", o, lp["wo"]), *_res_spec(cfg))
    if return_kv:
        return out, (ckv, k_rope[:, 0])
    return out


def forward(cfg: LMConfig, params, tokens):
    """tokens (B, S) -> (logits (B, S, V) f32, aux_loss)."""
    b, s = tokens.shape
    x = constrain(params["embed"][tokens], *_res_spec(cfg))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = cfg.window_pattern()

    def layer_fn(x, scanned):
        lp, window = scanned
        if cfg.grad_cast:
            # place the seq-parallel all-gather on the bf16 primal (GSPMD
            # otherwise gathers rmsnorm's f32 upcast: 2x the bytes)
            x = constrain(x, "batch", None, None)
        h = rmsnorm(x, lp["ln1"])
        if cfg.attn_kind == "mla":
            x = x + _mla_attention(cfg, lp, h, window, positions)
        else:
            x = x + _gqa_attention(cfg, lp, h, window, positions)
        h = rmsnorm(x, lp["ln2"])
        if cfg.moe:
            y, aux = moe_lib.moe_apply(
                lp["moe"], h.reshape(b * s, -1), top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, groups=cfg.moe_groups)
            y = constrain(y.reshape(b, s, -1), *_res_spec(cfg))
            return x + y, aux
        y = constrain(jnp.einsum("bsd,df->bsf", h, lp["w_gate"]),
                      "batch", None, "model")
        u = constrain(jnp.einsum("bsd,df->bsf", h, lp["w_up"]),
                      "batch", None, "model")
        dn = constrain(jnp.einsum("bsf,fd->bsd", jax.nn.silu(y) * u,
                                  lp["w_down"]), *_res_spec(cfg))
        return x + dn, jnp.float32(0)

    body = layer_fn
    if cfg.remat:
        body = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, scanned):
        # Megatron-style sequence parallelism for the remat-saved carry:
        # the per-layer saved activation shards its sequence dim over
        # 'model' (40 x 1.07 GiB/device replicated saves would not fit a
        # 16 GiB chip; sharded saves are 40 x 67 MiB).
        x = constrain(x, "batch", "model", None)
        if cfg.grad_cast:
            x = grad_cast_barrier(x)
        x, aux = body(x, scanned)
        return x, aux

    x, auxs = jax.lax.scan(scan_body, x, (params["layers"], windows))
    x = rmsnorm(x, params["final_ln"])
    logits = constrain(
        jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                   params["embed"].astype(jnp.float32)),
        "batch", None, "model")
    return logits, jnp.sum(auxs)


def hidden_states(cfg: LMConfig, params, tokens):
    """Transformer trunk -> (final hidden (B, S, D), aux)."""
    b, s = tokens.shape
    x = constrain(params["embed"][tokens], *_res_spec(cfg))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = cfg.window_pattern()

    def layer_fn(x, scanned):
        lp, window = scanned
        if cfg.grad_cast:
            # place the seq-parallel all-gather on the bf16 primal (GSPMD
            # otherwise gathers rmsnorm's f32 upcast: 2x the bytes)
            x = constrain(x, "batch", None, None)
        h = rmsnorm(x, lp["ln1"])
        if cfg.attn_kind == "mla":
            x = x + _mla_attention(cfg, lp, h, window, positions)
        else:
            x = x + _gqa_attention(cfg, lp, h, window, positions)
        h = rmsnorm(x, lp["ln2"])
        if cfg.moe:
            y, aux = moe_lib.moe_apply(
                lp["moe"], h.reshape(b * s, -1), top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, groups=cfg.moe_groups)
            y = constrain(y.reshape(b, s, -1), *_res_spec(cfg))
            return x + y, aux
        y = constrain(jnp.einsum("bsd,df->bsf", h, lp["w_gate"]),
                      "batch", None, "model")
        u = constrain(jnp.einsum("bsd,df->bsf", h, lp["w_up"]),
                      "batch", None, "model")
        dn = constrain(jnp.einsum("bsf,fd->bsd", jax.nn.silu(y) * u,
                                  lp["w_down"]), *_res_spec(cfg))
        return x + dn, jnp.float32(0)

    body = layer_fn
    if cfg.remat:
        body = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, scanned):
        x = constrain(x, "batch", "model", None)
        if cfg.grad_cast:
            x = grad_cast_barrier(x)
        return body(x, scanned)

    x, auxs = jax.lax.scan(scan_body, x, (params["layers"], windows))
    return rmsnorm(x, params["final_ln"]), jnp.sum(auxs)


def loss_fn(cfg: LMConfig, params, batch, loss_chunk: int = 512):
    """Sequence-chunked CE: the (B, chunk, V) logits block is the only
    vocab-sized live tensor (rematted, so backward recomputes it too)."""
    x, aux = hidden_states(cfg, params, batch["tokens"])
    b, s, d = x.shape
    labels = batch["labels"]
    c = min(loss_chunk, s)
    n = s // c
    xc = jnp.moveaxis(x.reshape(b, n, c, d), 1, 0)          # (n, B, C, D)
    lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)        # (n, B, C)
    embed = params["embed"]

    @jax.checkpoint
    def chunk_ce(carry, inp):
        nll_sum, cnt = carry
        xs, ls = inp
        logits = constrain(
            jnp.einsum("bcd,vd->bcv", xs.astype(jnp.float32),
                       embed.astype(jnp.float32)),
            "batch", None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(
            jnp.where(iota == jnp.maximum(ls, 0)[..., None], logits, 0.0),
            axis=-1)
        mask = (ls != -1).astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * mask),
                cnt + jnp.sum(mask)), None

    (nll_sum, cnt), _ = jax.lax.scan(
        chunk_ce, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    ce = nll_sum / jnp.maximum(cnt, 1.0)
    return ce + cfg.aux_loss_coef * aux, {"ce": ce, "aux": aux}


def prefill(cfg: LMConfig, params, tokens, max_len: int | None = None):
    """Prefill pass: (last-token logits (B, V), KV cache at len S).

    Emits per-layer caches from the layer scan; never materializes (B, S, V)
    logits (at 32k x 256k vocab that tensor would be petabytes).
    """
    b, s = tokens.shape
    max_len = max_len or s
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = cfg.window_pattern()
    dt = _dt(cfg)

    def layer_fn(x, scanned):
        lp, window = scanned
        if cfg.grad_cast:
            # place the seq-parallel all-gather on the bf16 primal (GSPMD
            # otherwise gathers rmsnorm's f32 upcast: 2x the bytes)
            x = constrain(x, "batch", None, None)
        h = rmsnorm(x, lp["ln1"])
        if cfg.attn_kind == "mla":
            o, (ckv, k_rope) = _mla_attention(
                cfg, lp, h, window, positions, return_kv=True)
            x = x + o
            kv_out = (_pad_cache(ckv, max_len), _pad_cache(k_rope, max_len))
        else:
            o, (k, v) = _gqa_attention(
                cfg, lp, h, window, positions, return_kv=True)
            x = x + o
            kv_out = (_pad_cache(k, max_len, axis=2),
                      _pad_cache(v, max_len, axis=2))
        h2 = rmsnorm(x, lp["ln2"])
        if cfg.moe:
            y, _ = moe_lib.moe_apply(
                lp["moe"], h2.reshape(b * s, -1), top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, groups=cfg.moe_groups)
            x = x + y.reshape(b, s, -1)
        else:
            y = constrain(jnp.einsum("bsd,df->bsf", h2, lp["w_gate"]),
                          "batch", None, "model")
            u = constrain(jnp.einsum("bsd,df->bsf", h2, lp["w_up"]),
                          "batch", None, "model")
            x = x + constrain(jnp.einsum("bsf,fd->bsd", jax.nn.silu(y) * u,
                                         lp["w_down"]), *_res_spec(cfg))
        return x, kv_out

    x, caches = jax.lax.scan(layer_fn, x, (params["layers"], windows))
    x_last = rmsnorm(x[:, -1], params["final_ln"])
    logits = jnp.einsum("bd,vd->bv", x_last.astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    if cfg.attn_kind == "mla":
        cache = {"ckv": caches[0], "krope": caches[1],
                 "len": jnp.asarray(s, jnp.int32)}
    else:
        cache = {"k": caches[0], "v": caches[1],
                 "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def _pad_cache(x, max_len: int, axis: int = 1):
    if x.shape[axis] == max_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, max_len - x.shape[axis])
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int):
    dt = _dt(cfg)
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dt),
            "krope": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dt),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.head_dim), dt),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.head_dim), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def _gqa_decode_layer(cfg, lp, h, kc, vc, pos, window):
    b = h.shape[0]
    hds, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,de->be", h, lp["wq"]).reshape(b, hds, 1, dh)
    k = jnp.einsum("bd,de->be", h, lp["wk"]).reshape(b, hkv, 1, dh)
    v = jnp.einsum("bd,de->be", h, lp["wv"]).reshape(b, hkv, 1, dh)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posb[:, None], cfg.rope_theta)
    k = apply_rope(k, posb[:, None], cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, pos, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, pos, 0))
    o = attn.decode_attention(q, kc, vc, pos + 1, window=window)
    o = o.reshape(b, hds * dh)
    return jnp.einsum("be,ed->bd", o, lp["wo"]), kc, vc


def _mla_decode_layer(cfg, lp, h, ckv_c, krope_c, pos):
    """Absorbed-projection MLA decode: attention runs in the compressed
    c_kv space; per-step FLOPs scale with kv_lora_rank, not H * head_dim."""
    b = h.shape[0]
    hds = cfg.n_heads
    nope, rope, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                         cfg.kv_lora_rank)
    q = jnp.einsum("bd,de->be", h, lp["wq"]).reshape(b, hds, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope[:, :, None], posb[:, None], cfg.rope_theta)[
        :, :, 0]
    new = jnp.einsum("bd,de->be", h, lp["w_dkv"])
    ckv_new, krope_new = new[..., :r], new[..., r:]
    krope_new = apply_rope(krope_new[:, None, None], posb[:, None],
                           cfg.rope_theta)[:, 0, 0]
    ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv_new[:, None], (0, pos, 0))
    krope_c = jax.lax.dynamic_update_slice(
        krope_c, krope_new[:, None], (0, pos, 0))
    # absorb W_uk into q: (b,h,nope) x (r, h, nope) -> (b, h, r)
    w_ukv = lp["w_ukv"].reshape(r, hds, nope + dv)
    w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]
    # bf16 dots with f32 accumulation — converting the compressed cache to
    # f32 would get hoisted out of the layer scan (see decode_attention).
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / ((nope + rope) ** 0.5)
    s_c = jnp.einsum("bhr,bsr->bhs", q_abs.astype(ckv_c.dtype), ckv_c,
                     preferred_element_type=jnp.float32) * scale
    s_r = jnp.einsum("bhr,bsr->bhs", q_rope.astype(krope_c.dtype), krope_c,
                     preferred_element_type=jnp.float32) * scale
    s = s_c + s_r
    mask = jnp.arange(ckv_c.shape[1])[None, None, :] > pos
    s = jnp.where(mask, -jnp.inf, s)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_c.dtype), ckv_c,
                     preferred_element_type=jnp.float32)  # (b,h,r)
    o = jnp.einsum("bhr,rhv->bhv", o_c.astype(w_uv.dtype), w_uv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, hds * dv).astype(h.dtype)
    return jnp.einsum("be,ed->bd", o, lp["wo"]), ckv_c, krope_c


def decode_step(cfg: LMConfig, params, cache, tokens):
    """One greedy decode step. tokens (B,) int32 -> (logits (B, V), cache).

    The full (L, ...) cache rides in the scan CARRY with per-layer
    dynamic_update_index_in_dim — carrying it as scan xs/ys double-buffers
    the multi-GiB cache (xs read + ys write are distinct buffers), which
    the dry-run showed as an extra full cache copy per device.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens]
    pos = cache["len"]
    windows = cfg.window_pattern()
    lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    def ffn(lp, x, h):
        if cfg.moe:
            y, _ = moe_lib.moe_apply(
                lp["moe"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor)
            return x + y
        y = jnp.einsum("bd,df->bf", h, lp["w_gate"])
        u = jnp.einsum("bd,df->bf", h, lp["w_up"])
        return x + jnp.einsum("bf,fd->bd", jax.nn.silu(y) * u, lp["w_down"])

    if cfg.attn_kind == "mla":
        def layer(carry, scanned):
            x, ckv_all, krope_all = carry
            lp, _w, i = scanned
            h = rmsnorm(x, lp["ln1"])
            ckv_c = jax.lax.dynamic_index_in_dim(ckv_all, i, 0, False)
            krope_c = jax.lax.dynamic_index_in_dim(krope_all, i, 0, False)
            o, ckv_c, krope_c = _mla_decode_layer(
                cfg, lp, h, ckv_c, krope_c, pos)
            ckv_all = jax.lax.dynamic_update_index_in_dim(
                ckv_all, ckv_c, i, 0)
            krope_all = jax.lax.dynamic_update_index_in_dim(
                krope_all, krope_c, i, 0)
            x = x + o
            h = rmsnorm(x, lp["ln2"])
            return (ffn(lp, x, h), ckv_all, krope_all), None

        (x, ckv, krope), _ = jax.lax.scan(
            layer, (x, cache["ckv"], cache["krope"]),
            (params["layers"], windows, lidx))
        new_cache = {"ckv": ckv, "krope": krope, "len": pos + 1}
    else:
        def layer(carry, scanned):
            x, k_all, v_all = carry
            lp, window, i = scanned
            h = rmsnorm(x, lp["ln1"])
            kc = jax.lax.dynamic_index_in_dim(k_all, i, 0, False)
            vc = jax.lax.dynamic_index_in_dim(v_all, i, 0, False)
            o, kc, vc = _gqa_decode_layer(cfg, lp, h, kc, vc, pos, window)
            k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, i, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, i, 0)
            x = x + o
            h = rmsnorm(x, lp["ln2"])
            return (ffn(lp, x, h), k_all, v_all), None

        (x, kcs, vcs), _ = jax.lax.scan(
            layer, (x, cache["k"], cache["v"]),
            (params["layers"], windows, lidx))
        new_cache = {"k": kcs, "v": vcs, "len": pos + 1}

    x = rmsnorm(x, params["final_ln"])
    logits = jnp.einsum("bd,vd->bv", x.astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return logits, new_cache
