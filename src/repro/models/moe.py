"""Mixture-of-Experts FFN: shared + routed experts, top-k, sort-based dispatch.

DeepSeek-V2-Lite / Moonlight family: n_shared always-active experts plus
n_experts routed with top_k selection and normalized gate weights.

Dispatch is the TPU-idiomatic sort-based scheme with static per-expert
capacity: flatten (token, choice) pairs, argsort by expert, compute each
pair's slot within its expert via a segmented rank, gather into a dense
(E, C, d) batch, run a batched einsum FFN, scatter-add back with gate
weights.  Tokens over capacity are dropped (standard capacity-factor
semantics); the router aux loss keeps load balanced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.constrain import constrain
from repro.models.layers import dense_init


def moe_init(key, d_model: int, d_expert: int, n_experts: int, n_shared: int,
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": dense_init(ks[1], d_model, d_expert, dtype)[None].repeat(
            n_experts, 0),
        "w_up": dense_init(ks[2], d_model, d_expert, dtype)[None].repeat(
            n_experts, 0),
        "w_down": dense_init(ks[3], d_expert, d_model, dtype)[None].repeat(
            n_experts, 0),
    }
    if n_shared > 0:
        p["shared"] = {
            "w_gate": dense_init(ks[4], d_model, n_shared * d_expert, dtype),
            "w_up": dense_init(ks[5], d_model, n_shared * d_expert, dtype),
            "w_down": dense_init(ks[6], n_shared * d_expert, d_model, dtype),
        }
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              groups: int = 0):
    """x (T, d) -> (out (T, d), aux_loss scalar).

    groups > 0 splits tokens into G independent dispatch groups (GShard
    style).  With G = number of data shards, the argsort/scatter run
    group-locally (no cross-shard resharding of the 6M-element sort) and
    the only surviving collective is the (G, E, C, d) -> expert-sharded
    all-to-all.  Capacity is per group, so drop behaviour changes slightly
    vs the global dispatch (documented; the router aux loss still balances
    globally via the mean over groups).
    """
    if groups > 1:
        return _moe_apply_grouped(params, x, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  groups=groups)
    t, d = x.shape
    e = params["router"].shape[1]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(1), axis=0
    ) / top_k
    aux = e * jnp.sum(me * ce)

    cap = max(1, int(capacity_factor * t * top_k / e))
    # flatten (token, choice) pairs and sort by expert
    flat_e = gate_idx.reshape(-1)                               # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)  # (T*K,)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                                 # stable
    se, stok, sw = flat_e[order], flat_t[order], flat_w[order]
    # slot of each pair within its expert
    pos = jnp.arange(t * top_k, dtype=jnp.int32)
    isfirst = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    grp_start = jnp.zeros((e,), jnp.int32).at[se].max(jnp.where(isfirst, pos, 0))
    slot = pos - grp_start[se]
    keep = slot < cap
    # gather tokens into (E, C) index table; dummy rows index t (a zero row)
    idx = jnp.full((e, cap), t, jnp.int32).at[
        jnp.where(keep, se, e - 1), jnp.where(keep, slot, cap - 1)
    ].min(jnp.where(keep, stok, t))
    wtbl = jnp.zeros((e, cap), jnp.float32).at[
        jnp.where(keep, se, e - 1), jnp.where(keep, slot, cap - 1)
    ].max(jnp.where(keep, sw, 0.0))
    xz = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], 0)
    xe = constrain(xz[idx], "model", None, None)                # (E, C, d)
    g = constrain(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]),
                  "model", None, None)
    u = constrain(jnp.einsum("ecd,edf->ecf", xe, params["w_up"]),
                  "model", None, None)
    y = constrain(jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                             params["w_down"]), "model", None, None)
    yw = y.astype(jnp.float32) * wtbl[..., None]
    out = jnp.zeros((t + 1, d), jnp.float32).at[idx.reshape(-1)].add(
        yw.reshape(-1, d)
    )[:t]

    if "shared" in params:
        sp = params["shared"]
        gs = constrain(jnp.einsum("td,df->tf", x, sp["w_gate"]),
                       "batch", "model")
        us = constrain(jnp.einsum("td,df->tf", x, sp["w_up"]),
                       "batch", "model")
        out = out + constrain(
            jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us, sp["w_down"]),
            "batch", None).astype(jnp.float32)
    return out.astype(x.dtype), aux


def _moe_apply_grouped(params, x, *, top_k: int, capacity_factor: float,
                       groups: int):
    """Group-local dispatch (see moe_apply docstring)."""
    t, d = x.shape
    g = groups
    assert t % g == 0, (t, g)
    tl = t // g
    e = params["router"].shape[1]
    xg = constrain(x.reshape(g, tl, d), "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Tl, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # (G, Tl, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(2),
                  axis=(0, 1)) / top_k
    aux = e * jnp.sum(me * ce)

    cap = max(1, int(capacity_factor * tl * top_k / e))
    flat_e = gate_idx.reshape(g, tl * top_k)
    flat_t = jnp.tile(
        jnp.repeat(jnp.arange(tl, dtype=jnp.int32), top_k)[None], (g, 1))
    flat_w = gate_vals.reshape(g, tl * top_k)
    order = jnp.argsort(flat_e, axis=1)
    gi = jnp.arange(g, dtype=jnp.int32)[:, None]
    se = jnp.take_along_axis(flat_e, order, 1)
    stok = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    pos = jnp.arange(tl * top_k, dtype=jnp.int32)[None]
    isfirst = jnp.concatenate(
        [jnp.ones((g, 1), bool), se[:, 1:] != se[:, :-1]], 1)
    grp_start = jnp.zeros((g, e), jnp.int32).at[gi, se].max(
        jnp.where(isfirst, pos, 0))
    slot = pos - jnp.take_along_axis(grp_start, se, 1)
    keep = slot < cap
    idx = jnp.full((g, e, cap), tl, jnp.int32).at[
        gi, jnp.where(keep, se, e - 1), jnp.where(keep, slot, cap - 1)
    ].min(jnp.where(keep, stok, tl))
    wtbl = jnp.zeros((g, e, cap), jnp.float32).at[
        gi, jnp.where(keep, se, e - 1), jnp.where(keep, slot, cap - 1)
    ].max(jnp.where(keep, sw, 0.0))
    xz = jnp.concatenate([xg, jnp.zeros((g, 1, d), x.dtype)], 1)
    xe = xz[gi[:, :, None], idx]                                # (G, E, C, d)
    xe = constrain(xe, "batch", "model", None, None)
    gg = constrain(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]),
                   "batch", "model", None, None)
    uu = constrain(jnp.einsum("gecd,edf->gecf", xe, params["w_up"]),
                   "batch", "model", None, None)
    y = constrain(jnp.einsum("gecf,efd->gecd", jax.nn.silu(gg) * uu,
                             params["w_down"]), "batch", "model", None, None)
    yw = y.astype(jnp.float32) * wtbl[..., None]
    out = jnp.zeros((g, tl + 1, d), jnp.float32).at[
        gi[:, :, None], idx
    ].add(yw)[:, :tl].reshape(t, d)
    out = constrain(out, "batch", None)

    if "shared" in params:
        sp = params["shared"]
        gs = constrain(jnp.einsum("td,df->tf", x, sp["w_gate"]),
                       "batch", "model")
        us = constrain(jnp.einsum("td,df->tf", x, sp["w_up"]),
                       "batch", "model")
        out = out + constrain(
            jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us, sp["w_down"]),
            "batch", None).astype(jnp.float32)
    return out.astype(x.dtype), aux
