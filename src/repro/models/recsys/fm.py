"""Factorization Machine (Rendle, ICDM'10) with a hashed embedding table.

JAX has no nn.EmbeddingBag — lookups are jnp.take over a single hashed
table with per-field offsets (quotient-remainder-style id space), and the
second-order term is the fused Pallas fm_interaction kernel (sum-square
trick, O(F*D)).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.fm_interaction.ops import fm_interaction
from repro.models.layers import embed_init


@dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_fields: int = 39
    embed_dim: int = 10
    rows_per_field: int = 262144   # hashed vocabulary per sparse field
    dtype: str = "float32"

    @property
    def vocab_total(self) -> int:
        return self.n_fields * self.rows_per_field

    def param_count(self) -> int:
        return self.vocab_total * (self.embed_dim + 1) + 1


def init_params(cfg: FMConfig, key):
    k1, k2 = jax.random.split(key)
    return {
        "table": embed_init(k1, cfg.vocab_total, cfg.embed_dim,
                            jnp.dtype(cfg.dtype)),
        "linear": (jax.random.normal(k2, (cfg.vocab_total,), jnp.float32)
                   * 0.01),
        "bias": jnp.zeros((), jnp.float32),
    }


def _offsets(cfg: FMConfig):
    return (jnp.arange(cfg.n_fields, dtype=jnp.int32)
            * cfg.rows_per_field)[None, :]


def forward(cfg: FMConfig, params, ids):
    """ids (B, F) int32 per-field raw ids -> scores (B,)."""
    flat = (ids % cfg.rows_per_field) + _offsets(cfg)       # (B, F)
    emb = params["table"][flat]                              # (B, F, D)
    lin = params["linear"][flat]                             # (B, F)
    second = fm_interaction(emb, use_pallas=jax.default_backend() == "tpu")
    return (params["bias"] + jnp.sum(lin, -1)
            + second.astype(jnp.float32))


def loss_fn(cfg: FMConfig, params, batch):
    scores = forward(cfg, params, batch["ids"])
    y = batch["labels"].astype(jnp.float32)
    # BCE with logits
    loss = jnp.mean(
        jnp.maximum(scores, 0) - scores * y + jnp.log1p(jnp.exp(-jnp.abs(scores)))
    )
    return loss, {"auc_proxy": jnp.mean((scores > 0) == (y > 0.5))}


def serve(cfg: FMConfig, params, ids):
    """Online/bulk scoring path."""
    return forward(cfg, params, ids)


def retrieval_scores(cfg: FMConfig, params, user_ids, cand_ids):
    """Score one user against C candidate items (batched dot, no loop).

    FM score decomposes as const(u) + <sum_f v_uf, v_i> + lin_i for a single
    candidate field; we return the candidate-dependent part for ranking.
    user_ids (1, F-1); cand_ids (C,) raw ids in the item field (field F-1).
    """
    f_user = cfg.n_fields - 1
    flat_u = (user_ids % cfg.rows_per_field) + _offsets(cfg)[:, :f_user]
    u_emb = params["table"][flat_u]                    # (1, F-1, D)
    u_vec = jnp.sum(u_emb, axis=1)                     # (1, D)
    flat_c = (cand_ids % cfg.rows_per_field) + f_user * cfg.rows_per_field
    c_emb = params["table"][flat_c]                    # (C, D)
    c_lin = params["linear"][flat_c]                   # (C,)
    return (c_emb.astype(jnp.float32) @ u_vec[0].astype(jnp.float32)
            + c_lin)                                   # (C,)
