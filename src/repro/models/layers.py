"""Shared NN building blocks (raw-pytree params; no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16):
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32, -scale, scale)
            .astype(dtype))


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x (..., S, D) with D even; positions (..., S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, ignore_id: int = -1):
    """logits (..., V) f32-accumulated CE with masking.

    The gold logit is extracted with a masked sum over V rather than
    take_along_axis: a gather along a vocab-sharded axis makes GSPMD
    replicate the full logits tensor (verified in the 512-device dry-run:
    67 GiB/device for command-r), while the masked sum stays sharded and
    lowers to a local select + all-reduce.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = iota == jnp.maximum(labels, 0)[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
