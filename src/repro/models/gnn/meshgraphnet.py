"""MeshGraphNet (Pfaff et al. 2020): encode-process-decode mesh simulator.

The 15 identical processor blocks run as a scan over stacked params with
remat, and node/edge activations carry explicit row-sharding constraints —
on ogb_products-sized graphs the unconstrained version peaked at 55 GiB per
device in the dry-run; sharded carries bring it under 2 GiB.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.dist.constrain import constrain
from repro.models.gnn.common import (
    GraphBatch, edge_vectors, gather_nodes, mlp_apply, mlp_init, scatter_sum,
)


@dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_in: int = 4          # node input features
    d_edge_in: int = 4     # rel pos (3) + dist (1)
    d_out: int = 2
    dtype: str = "float32"

    def _mlp(self, d_in):
        return d_in * self.d_hidden + (self.mlp_layers - 1) * self.d_hidden ** 2

    def param_count(self) -> int:
        enc = self._mlp(self.d_in) + self._mlp(self.d_edge_in)
        proc = self.n_layers * (self._mlp(3 * self.d_hidden)
                                + self._mlp(2 * self.d_hidden))
        dec = self._mlp(self.d_hidden) // self.d_hidden * self.d_out
        return enc + proc + self.d_hidden * self.d_out


def _mlp_dims(cfg, d_in, d_out=None):
    return (d_in,) + (cfg.d_hidden,) * (cfg.mlp_layers - 1) + (
        d_out or cfg.d_hidden,)


def init_params(cfg: MGNConfig, key):
    ks = jax.random.split(key, 4)
    enc_n = mlp_init(ks[0], _mlp_dims(cfg, cfg.d_in))
    enc_e = mlp_init(ks[1], _mlp_dims(cfg, cfg.d_edge_in))
    bkeys = jax.random.split(ks[2], cfg.n_layers)

    def one_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": mlp_init(k1, _mlp_dims(cfg, 3 * cfg.d_hidden)),
            "node": mlp_init(k2, _mlp_dims(cfg, 2 * cfg.d_hidden)),
        }

    blocks = jax.vmap(one_block)(bkeys)  # stacked (L, ...) leaves
    dec = mlp_init(ks[3], _mlp_dims(cfg, cfg.d_hidden, cfg.d_out))
    return {"enc_n": enc_n, "enc_e": enc_e, "blocks": blocks, "dec": dec}


def forward(cfg: MGNConfig, params, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    rel, dist, valid = edge_vectors(batch)
    efeat = jnp.concatenate([rel, dist[:, None]], -1)
    h = mlp_apply(params["enc_n"], batch.node_feat, act=jax.nn.relu)
    e = mlp_apply(params["enc_e"], efeat, act=jax.nn.relu)
    e = e * valid[:, None]

    @jax.checkpoint
    def block(carry, blk):
        h, e = carry
        h = constrain(h, "all", None)
        e = constrain(e, "all", None)
        hs = gather_nodes(h, batch.senders)
        hr = gather_nodes(h, batch.receivers)
        e = e + mlp_apply(blk["edge"], jnp.concatenate([e, hs, hr], -1),
                          act=jax.nn.relu) * valid[:, None]
        agg = scatter_sum(e, batch.receivers, n)
        h = h + mlp_apply(blk["node"], jnp.concatenate([h, agg], -1),
                          act=jax.nn.relu)
        return (constrain(h, "all", None), constrain(e, "all", None)), None

    (h, e), _ = jax.lax.scan(block, (h, e), params["blocks"])
    return mlp_apply(params["dec"], h, act=jax.nn.relu)  # (N, d_out)


def loss_fn(cfg: MGNConfig, params, batch_and_labels):
    batch, target = batch_and_labels["graph"], batch_and_labels["target"]
    pred = forward(cfg, params, batch)
    mask = (batch.graph_id < batch.n_graphs).astype(jnp.float32)[:, None]
    loss = jnp.sum(((pred - target) ** 2) * mask) / jnp.maximum(
        jnp.sum(mask) * cfg.d_out, 1.0)
    return loss, {}
