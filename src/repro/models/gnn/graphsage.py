"""GraphSAGE (Hamilton et al. 2017): sampled mean-aggregation node classifier."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    GraphBatch, gather_nodes, mlp_init, scatter_mean,
)
from repro.models.layers import cross_entropy_loss, dense_init


@dataclass(frozen=True)
class SageConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    aggregator: str = "mean"
    sample_sizes: tuple = (25, 10)
    dtype: str = "float32"

    def param_count(self) -> int:
        total, d = 0, self.d_in
        for i in range(self.n_layers):
            out = self.n_classes if i == self.n_layers - 1 else self.d_hidden
            total += 2 * d * out
            d = out
        return total


def init_params(cfg: SageConfig, key):
    dt = jnp.dtype(cfg.dtype)
    layers = []
    d = cfg.d_in
    ks = jax.random.split(key, cfg.n_layers)
    for i in range(cfg.n_layers):
        out = cfg.n_classes if i == cfg.n_layers - 1 else cfg.d_hidden
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "w_self": dense_init(k1, d, out, dt),
            "w_neigh": dense_init(k2, d, out, dt),
        })
        d = out
    return {"layers": layers}


def forward(cfg: SageConfig, params, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    h = batch.node_feat
    for i, lp in enumerate(params["layers"]):
        msg = gather_nodes(h, batch.senders)
        agg = scatter_mean(msg, batch.receivers, n)
        h_new = (h @ lp["w_self"] + agg @ lp["w_neigh"])
        if i < cfg.n_layers - 1:
            h_new = jax.nn.relu(h_new)
            # L2 normalize (paper's trick for stability)
            h_new = h_new / jnp.maximum(
                jnp.linalg.norm(h_new, axis=-1, keepdims=True), 1e-6)
        h = h_new
    return h  # (N, n_classes) logits


def loss_fn(cfg: SageConfig, params, batch_and_labels):
    batch, labels = batch_and_labels["graph"], batch_and_labels["labels"]
    logits = forward(cfg, params, batch)
    return cross_entropy_loss(logits, labels), {}
