"""NequIP-lite (Batzner et al. 2021): E(3)-equivariant interatomic potential.

Faithful pieces: l_max=2 irrep features (scalars, vectors, traceless
symmetric rank-2 tensors), radial MLP on a Bessel/Gaussian basis, cutoff
envelope, gated equivariant nonlinearity, per-atom energy readout.

TPU adaptation (DESIGN.md §6): the full Clebsch-Gordan tensor product is
replaced by the closed-form l<=2 covariant products (dot, cross, outer -
trace, tensor contraction) — every path below transforms correctly under
O(3), which tests/test_models_gnn.py verifies with random rotations.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.dist.constrain import constrain
from repro.models.gnn.common import (
    GraphBatch, cosine_cutoff, edge_vectors, gather_nodes, mlp_apply,
    mlp_init, rbf_expand, scatter_sum,
)
from repro.models.layers import embed_init

_EYE3 = jnp.eye(3)


def _y2(rhat):
    """l=2 spherical tensor: traceless symmetric outer product (E, 3, 3)."""
    outer = rhat[:, :, None] * rhat[:, None, :]
    return outer - _EYE3[None] / 3.0


def _sym_traceless(t):
    sym = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(sym, axis1=-2, axis2=-1)
    return sym - tr[..., None, None] * _EYE3 / 3.0


@dataclass(frozen=True)
class NequipConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32      # channels per irrep
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 20
    dtype: str = "float32"
    n_paths: int = 8        # radial outputs per layer (see _interact)

    def param_count(self) -> int:
        c, r = self.d_hidden, self.n_rbf
        radial = r * 32 + 32 * (self.n_paths * c)
        mix = 6 * c * c
        return (self.n_species * c
                + self.n_layers * (radial + mix)
                + c * 16 + 16)


def init_params(cfg: NequipConfig, key):
    ks = jax.random.split(key, 3)
    c = cfg.d_hidden

    def one_layer(k):
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        return {
            "radial": mlp_init(k1, (cfg.n_rbf, 32, cfg.n_paths * c)),
            "mix_s": mlp_init(k2, (2 * c, c)),
            "mix_v": mlp_init(k3, (c, c)),     # channel mix of vectors
            "mix_t": mlp_init(k4, (c, c)),     # channel mix of tensors
            "gate": mlp_init(k5, (c, 2 * c)),  # gates for V and T
        }

    layers = jax.vmap(one_layer)(jax.random.split(ks[0], cfg.n_layers))
    return {
        "embed": embed_init(ks[1], cfg.n_species, c, jnp.float32),
        "layers": layers,   # stacked (L, ...) leaves -> scanned
        "head": mlp_init(ks[2], (c, 16, 1)),
    }


def _interact(cfg, lp, s, V, T, batch, rbf, env, rhat):
    """One equivariant message-passing layer.

    s (N, C) scalars; V (N, C, 3) vectors; T (N, C, 3, 3) traceless sym.
    """
    n, c = s.shape
    R = mlp_apply(lp["radial"], rbf, act=jax.nn.silu) * env  # (E, P*C)
    R = R.reshape(R.shape[0], cfg.n_paths, c)                # (E, P, C)
    s_j = gather_nodes(s, batch.senders)                     # (E, C)
    V_j = gather_nodes(V, batch.senders)                     # (E, C, 3)
    T_j = gather_nodes(T, batch.senders)                     # (E, C, 3, 3)
    y2 = _y2(rhat)                                           # (E, 3, 3)

    # --- covariant products (paths), all O(3)-equivariant:
    # scalars: l0xl0->l0, l1.Y1->l0, T:Y2->l0
    m_s = (R[:, 0] * s_j
           + R[:, 1] * jnp.einsum("eci,ei->ec", V_j, rhat)
           + R[:, 2] * jnp.einsum("ecij,eij->ec", T_j, y2))
    # vectors: l0xY1->l1, l1xl0->l1, l1 x Y1 (cross) -> l1, T.Y1->l1
    m_v = (R[:, 3, :, None] * s_j[:, :, None] * rhat[:, None, :]
           + R[:, 4, :, None] * V_j
           + R[:, 5, :, None] * jnp.cross(
               V_j, jnp.broadcast_to(rhat[:, None, :], V_j.shape))
           + R[:, 6, :, None] * jnp.einsum("ecij,ej->eci", T_j, rhat))
    # tensors: l0xY2->l2, sym(V (x) r)->l2
    m_t = (R[:, 7, :, None, None] * s_j[:, :, None, None] * y2[:, None]
           + _sym_traceless(
               R[:, 4, :, None, None]
               * V_j[:, :, :, None] * rhat[:, None, None, :]))

    ds = scatter_sum(m_s, batch.receivers, n)
    dV = scatter_sum(m_v, batch.receivers, n)
    dT = scatter_sum(m_t, batch.receivers, n)

    # --- node update: invariant pathway + gated equivariant channels
    v_norm = jnp.sqrt(jnp.sum(dV * dV, axis=-1) + 1e-12)     # (N, C) invariant
    s_new = s + mlp_apply(lp["mix_s"], jnp.concatenate([ds, v_norm], -1),
                          act=jax.nn.silu)
    gates = jax.nn.sigmoid(mlp_apply(lp["gate"], s_new))      # (N, 2C)
    gv, gt = gates[:, :c], gates[:, c:]
    V_new = V + gv[:, :, None] * jnp.einsum(
        "ncj,cd->ndj", dV, lp["mix_v"][0]["w"])
    T_new = T + gt[:, :, None, None] * jnp.einsum(
        "ncij,cd->ndij", dT, lp["mix_t"][0]["w"])
    return s_new, V_new, T_new


def forward(cfg: NequipConfig, params, batch: GraphBatch):
    n = batch.node_feat.shape[0]
    c = cfg.d_hidden
    z = batch.node_feat[:, 0].astype(jnp.int32)
    s = params["embed"][jnp.clip(z, 0, cfg.n_species - 1)]
    V = jnp.zeros((n, c, 3), jnp.float32)
    T = jnp.zeros((n, c, 3, 3), jnp.float32)
    rel, dist, valid = edge_vectors(batch)
    rhat = rel / jnp.maximum(dist, 1e-9)[:, None]
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    env = (cosine_cutoff(dist, cfg.cutoff) * valid)[:, None]

    @jax.checkpoint
    def layer(carry, lp):
        s, V, T = carry
        s = constrain(s, "all", None)
        V = constrain(V, "all", None, None)
        T = constrain(T, "all", None, None, None)
        s, V, T = _interact(cfg, lp, s, V, T, batch, rbf, env, rhat)
        return (s, V, T), None

    (s, V, T), _ = jax.lax.scan(layer, (s, V, T), params["layers"])
    atom_e = mlp_apply(params["head"], s, act=jax.nn.silu)[:, 0]
    return jax.ops.segment_sum(
        atom_e, batch.graph_id, num_segments=batch.n_graphs + 1
    )[: batch.n_graphs]


def loss_fn(cfg: NequipConfig, params, batch_and_labels):
    batch, energy = batch_and_labels["graph"], batch_and_labels["energy"]
    pred = forward(cfg, params, batch)
    loss = jnp.mean((pred - energy) ** 2)
    return loss, {"mae": jnp.mean(jnp.abs(pred - energy))}
