"""Shared GNN machinery: padded graph batches + segment message passing.

JAX has no sparse message passing — per the assignment, EmbeddingBag/SpMM
style aggregation is built from ``jnp.take`` + ``jax.ops.segment_sum`` over
an edge-index.  Convention: node arrays have N rows; edge indices live in
[0, N] where N is the ghost node (padding edges point there and are dropped
by slicing segment outputs to N).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class GraphBatch(NamedTuple):
    """Static-shape graph batch.

    senders/receivers: (E,) int32 in [0, N]; N = padding/ghost.
    node_feat: (N, F) float; pos: (N, 3) or zeros; graph_id: (N,) int32 in
    [0, G] mapping nodes to molecules/meshes (G = ghost graph for pad nodes).
    """

    node_feat: jnp.ndarray
    senders: jnp.ndarray
    receivers: jnp.ndarray
    edge_feat: jnp.ndarray | None
    pos: jnp.ndarray | None
    graph_id: jnp.ndarray
    n_graphs: int  # static


def scatter_sum(values, index, n: int):
    """values (E, ...), index (E,) in [0, n] -> (n, ...) (ghost dropped)."""
    return jax.ops.segment_sum(values, index, num_segments=n + 1)[:n]


def scatter_mean(values, index, n: int):
    s = scatter_sum(values, index, n)
    cnt = scatter_sum(jnp.ones((values.shape[0],), jnp.float32), index, n)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)}
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def mlp_apply(params, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def rbf_expand(d, n_rbf: int, cutoff: float):
    """Gaussian radial basis on distances d (E,) -> (E, n_rbf)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (d[:, None] - centers[None, :]) ** 2)


def cosine_cutoff(d, cutoff: float):
    """Smooth envelope that zeroes messages at the cutoff radius."""
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    return 0.5 * (jnp.cos(jnp.pi * x) + 1.0)


def edge_vectors(batch: GraphBatch):
    """(E, 3) displacement, (E,) distance; padding edges give 0/0."""
    n = batch.node_feat.shape[0]
    pos = jnp.concatenate([batch.pos, jnp.zeros((1, 3), batch.pos.dtype)], 0)
    rel = pos[batch.receivers] - pos[batch.senders]
    dist = jnp.linalg.norm(rel + 1e-12, axis=-1)
    valid = (batch.senders < n) & (batch.receivers < n)
    return jnp.where(valid[:, None], rel, 0.0), jnp.where(valid, dist, 0.0), valid


def gather_nodes(x, index):
    """x (N, ...) gathered at (E,) indices in [0, N] (ghost row = zeros)."""
    xz = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], 0)
    return xz[index]
