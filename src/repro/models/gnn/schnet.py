"""SchNet (Schuett et al. 2017): continuous-filter convolutions for molecules."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.dist.constrain import constrain
from repro.models.gnn.common import (
    GraphBatch, cosine_cutoff, edge_vectors, gather_nodes, mlp_apply,
    mlp_init, rbf_expand, scatter_sum,
)
from repro.models.layers import embed_init


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    dtype: str = "float32"

    def param_count(self) -> int:
        d, r = self.d_hidden, self.n_rbf
        per = (r * d + d * d) + 3 * d * d  # filter net + in/out dense
        return self.n_species * d + self.n_interactions * per + d * (d // 2) + (d // 2)


def init_params(cfg: SchNetConfig, key):
    ks = jax.random.split(key, 3)

    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "filter": mlp_init(k1, (cfg.n_rbf, cfg.d_hidden, cfg.d_hidden)),
            "in": mlp_init(k2, (cfg.d_hidden, cfg.d_hidden)),
            "out": mlp_init(k3, (cfg.d_hidden, cfg.d_hidden, cfg.d_hidden)),
        }

    inter = jax.vmap(one)(jax.random.split(ks[0], cfg.n_interactions))
    return {
        "embed": embed_init(ks[1], cfg.n_species, cfg.d_hidden, jnp.float32),
        "interactions": inter,   # stacked (L, ...) leaves -> scanned
        "head": mlp_init(ks[2], (cfg.d_hidden, cfg.d_hidden // 2, 1)),
    }


def forward(cfg: SchNetConfig, params, batch: GraphBatch):
    """Per-graph energies (G,). node_feat[:, 0] carries the species id."""
    n = batch.node_feat.shape[0]
    z = batch.node_feat[:, 0].astype(jnp.int32)
    h = params["embed"][jnp.clip(z, 0, cfg.n_species - 1)]
    rel, dist, valid = edge_vectors(batch)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    env = (cosine_cutoff(dist, cfg.cutoff) * valid)[:, None]

    @jax.checkpoint
    def block(h, blk):
        h = constrain(h, "all", None)
        w = mlp_apply(blk["filter"], rbf, act=shifted_softplus,
                      final_act=True) * env            # (E, d)
        src = gather_nodes(mlp_apply(blk["in"], h), batch.senders)
        msg = constrain(src * w, "all", None)
        agg = scatter_sum(msg, batch.receivers, n)
        h = h + mlp_apply(blk["out"], agg, act=shifted_softplus)
        return constrain(h, "all", None), None

    h, _ = jax.lax.scan(block, h, params["interactions"])
    atom_e = mlp_apply(params["head"], h, act=shifted_softplus)[:, 0]  # (N,)
    return jax.ops.segment_sum(
        atom_e, batch.graph_id, num_segments=batch.n_graphs + 1
    )[: batch.n_graphs]


def loss_fn(cfg: SchNetConfig, params, batch_and_labels):
    batch, energy = batch_and_labels["graph"], batch_and_labels["energy"]
    pred = forward(cfg, params, batch)
    loss = jnp.mean((pred - energy) ** 2)
    return loss, {"mae": jnp.mean(jnp.abs(pred - energy))}
