"""Attention implementations: chunked online-softmax (jnp, the dry-run /
large-shape path, mathematically identical to the Pallas flash kernel) and
the cached decode path.  GQA, causal, sliding-window, MLA handled here.

Memory discipline (what the 512-device dry-run actually verified):
  * KV heads are repeated to the full head count *before* the scan — a
    (B, H, S, D) layout keeps the head axis cleanly sharded over 'model';
    the (hkv, group) strided view defeats GSPMD propagation and forced
    involuntary full remats.
  * The per-chunk step is wrapped in jax.checkpoint, so backward recomputes
    the (Sq, chunk) probability block instead of saving it: activation
    memory is O(S) per layer, not O(S^2) — the flash-backward trade made
    explicit in jnp.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def repeat_kv(k, h: int):
    """(B, Hkv, S, D) -> (B, H, S, D) by repeating each kv head."""
    b, hkv, s, d = k.shape
    if hkv == h:
        return k
    return jnp.repeat(k, h // hkv, axis=1)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk: int = 1024):
    """Flash-style online softmax over kv chunks via lax.scan.

    q (B, H, Sq, D); k, v (B, Hkv, Skv, Dk/Dv) — kv heads repeated here.
    ``window`` may be a traced scalar (0 = unlimited) so mixed local/global
    layers share one compiled body.  Never materializes (Sq, Skv).
    """
    b, h, sq, d = q.shape
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    skv = k.shape[2]
    dv = v.shape[-1]
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    nchunks = skv // chunk
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    qpos = (jnp.arange(sq, dtype=jnp.int32) + q_offset)[:, None]  # (Sq, 1)
    window = jnp.asarray(window, jnp.int32)

    @jax.checkpoint
    def step(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        s = jnp.einsum("bhqd,bhcd->bhqc", qf, kj.astype(jnp.float32))
        kpos = (j * chunk + jnp.arange(chunk, dtype=jnp.int32))[None, :]
        mask = jnp.zeros((sq, chunk), bool)
        if causal:
            mask = mask | (kpos > qpos)
        mask = mask | ((window > 0) & (kpos <= qpos - window))
        s = jnp.where(mask[None, None], -jnp.inf, s)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isneginf(m), -jnp.inf, m - m_safe))
        corr = jnp.where(jnp.isneginf(m), 0.0, corr)
        l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = corr * acc + jnp.einsum(
            "bhqc,bhcd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, h, nchunks, chunk, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, h, nchunks, chunk, dv), 2, 0)
    js = jnp.arange(nchunks, dtype=jnp.int32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, js))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0):
    """Single-token decode: q (B, H, 1, D); caches (B, Hkv, S, D).

    The cache is NOT repeated to H heads (that would multiply cache reads
    by the GQA group); the tiny q is viewed as (B, Hkv, group, D) instead.
    cache_len is the number of valid entries (the new token's kv must
    already be written at position cache_len - 1).  Linear in S.
    """
    b, h, _, d = q.shape
    _, hkv, s_len, _ = k_cache.shape
    group = h // hkv
    scale = 1.0 / (d ** 0.5)
    # NEVER convert the cache: a bf16->f32 astype gets hoisted out of the
    # layer scan by XLA, doubling the resident cache (dry-run: +6 GiB/dev
    # on moonshot decode).  bf16 x bf16 dots accumulate in f32 via
    # preferred_element_type instead.
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype).reshape(
        b, hkv, group, d)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache,
                    preferred_element_type=jnp.float32)
    kpos = jnp.arange(s_len, dtype=jnp.int32)[None, :]
    qpos = cache_len - 1
    mask = kpos >= cache_len
    window = jnp.asarray(window, jnp.int32)
    mask = mask | ((window > 0) & (kpos <= qpos - window))
    sc = jnp.where(mask[None, None], -jnp.inf, sc)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, 1, d).astype(q.dtype)
